//! Policy framework (§4.3): user-level knobs + system-level constants.
//!
//! User-level policies let each provider decide *when, under what policies,
//! and with what resources* it participates: its stake, how eagerly it
//! offloads, whether it accepts delegated work, and how it prioritizes its
//! own users. System-level policies are the network-wide economic constants
//! (base reward R, duel rate p_d, duel reward R_add, penalty P, judges k,
//! offload price) that every honest node enforces.
//!
//! The scalar knobs ([`NodePolicy`]) are only half the story: *how* a node
//! interprets them at the dispatch boundary is a pluggable
//! [`ParticipationPolicy`] (see [`participation`]) — offload-or-serve,
//! accept-or-reject-a-probe, candidate scoring, and maintenance gates —
//! with [`DefaultPolicy`] reproducing the knob behaviour draw-for-draw and
//! alternative personalities ([`RequesterOnly`], [`GreedyLocal`],
//! [`SelectiveAcceptor`]) selectable per fleet group from scenario configs.

//!
//! Byzantine personalities — free-riders, latency liars, result fakers,
//! colluders — live in [`byzantine`] and are selected per fleet group via
//! the `"byzantine"` config key; the defenses that counter them are
//! documented in `crate::reputation`.

pub mod byzantine;
pub mod participation;

pub use byzantine::{
    ByzantineKind, Colluder, FreeRider, LatencyLiar, ResultFaker,
};
pub use participation::{
    DefaultPolicy, GreedyLocal, OffloadCtx, ParticipationKind,
    ParticipationPolicy, ProbeCtx, RequesterOnly, SelectiveAcceptor,
};

use crate::types::{Credits, CREDIT};
use crate::util::rng::Rng;

/// Per-provider participation policy (Appendix B's YAML server parameters).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodePolicy {
    /// Credits the node stakes at join (its PoS weight; Fig. 8a).
    pub stake: Credits,
    /// Probability of *considering* offload for a queued request once the
    /// local backend is saturated (Fig. 8c; paper default 0.8).
    pub offload_freq: f64,
    /// Probability of accepting a delegated request when probed, given
    /// capacity (Fig. 8b; paper default 0.8).
    pub accept_freq: f64,
    /// Backend utilization (running/max_batch) above which the node prefers
    /// to offload rather than queue locally (paper default 0.7).
    pub target_utilization: f64,
    /// Queue length (waiting requests) beyond which offload is considered
    /// even below target utilization.
    pub queue_threshold: usize,
    /// If true, user-submitted jobs are dequeued before delegated ones.
    pub prioritize_own: bool,
    /// Refuse delegated work entirely (a "requester-only" node, used by the
    /// §7 ablation workloads).
    pub requester_only: bool,
    /// Locality preference for geo-distributed worlds (per-second weight).
    /// PoS candidate weights are damped by `1 / (1 + penalty * latency)`
    /// using the topology's expected one-way latency to the candidate, and
    /// `should_offload` is damped the same way by the latency of the
    /// *nearest* live candidate. 0 (default) reproduces region-blind
    /// dispatch exactly.
    pub latency_penalty: f64,
}

impl Default for NodePolicy {
    fn default() -> Self {
        NodePolicy {
            stake: 10 * CREDIT,
            offload_freq: 0.8,
            accept_freq: 0.8,
            target_utilization: 0.7,
            queue_threshold: 4,
            prioritize_own: true,
            requester_only: false,
            latency_penalty: 0.0,
        }
    }
}

impl NodePolicy {
    pub fn requester_only() -> Self {
        NodePolicy {
            stake: 0,
            offload_freq: 1.0,
            accept_freq: 0.0,
            requester_only: true,
            ..Default::default()
        }
    }

    /// Should this node try to offload a request right now?
    /// `utilization` = running/max_batch of the local backend,
    /// `queue_len` = requests waiting locally,
    /// `nearest_latency` = expected one-way latency to the closest live
    /// delegation candidate (0.0 in single-region worlds or when the node
    /// has no locality information).
    ///
    /// RNG discipline: at most one draw, taken only under pressure — with
    /// `latency_penalty == 0` the damping factor is exactly 1.0, so flat
    /// worlds replay bit-identically to the pre-topology behaviour.
    pub fn should_offload(
        &self,
        utilization: f64,
        queue_len: usize,
        nearest_latency: f64,
        rng: &mut Rng,
    ) -> bool {
        if self.requester_only {
            return true; // it cannot serve anything itself
        }
        let pressured = utilization >= self.target_utilization
            || queue_len > self.queue_threshold;
        if !pressured {
            return false;
        }
        let damp = 1.0 / (1.0 + self.latency_penalty * nearest_latency.max(0.0));
        rng.chance(self.offload_freq * damp)
    }

    /// Should this node accept a delegated request it was probed for?
    pub fn should_accept(
        &self,
        utilization: f64,
        queue_len: usize,
        rng: &mut Rng,
    ) -> bool {
        if self.requester_only || self.accept_freq <= 0.0 {
            return false;
        }
        // Accepting while saturated would only grow the remote queue; the
        // probe answers "do I have spare capacity" per the paper's example
        // ("accept external requests only when spare GPU capacity is
        // available").
        let has_capacity =
            utilization < 1.0 && queue_len <= self.queue_threshold;
        has_capacity && rng.chance(self.accept_freq)
    }
}

/// System-level economic constants (§4.3, §5 Assumption 5.2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SystemPolicy {
    /// Base payment a delegator transfers to the executor per request (R).
    pub base_reward: Credits,
    /// Fraction of delegated requests escalated to duels (p_d).
    pub duel_rate: f64,
    /// Extra minted reward for the duel winner (R_add).
    pub duel_reward: Credits,
    /// Stake slashed from the duel loser (P).
    pub duel_penalty: Credits,
    /// Judges per duel (k).
    pub judges: usize,
    /// Minted reward per judge evaluation.
    pub judge_reward: Credits,
    /// Max PoS probes before giving up and serving locally.
    pub max_probes: usize,
    /// Initial liquid credits granted to a joining node.
    pub genesis_credits: Credits,
    /// Majority threshold for blockchain-mode block confirmation, as a
    /// fraction of known peers.
    pub confirm_quorum: f64,
}

impl Default for SystemPolicy {
    fn default() -> Self {
        SystemPolicy {
            base_reward: CREDIT / 10,        // 0.1 credit per request
            duel_rate: 0.10,                 // paper's default ablation point
            duel_reward: CREDIT / 5,         // R_add
            duel_penalty: CREDIT / 5,        // P
            judges: 2,                       // k = 2 (§7.1 setup)
            judge_reward: CREDIT / 20,
            max_probes: 3,
            genesis_credits: 100 * CREDIT,
            confirm_quorum: 0.5,
        }
    }
}

impl SystemPolicy {
    /// Expected extra requests per delegated request from the duel-and-judge
    /// mechanism: p_d * (1 + k) (§7.1).
    pub fn duel_overhead_factor(&self) -> f64 {
        self.duel_rate * (1.0 + self.judges as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_policy_matches_paper_appendix_c() {
        let p = NodePolicy::default();
        assert!((p.offload_freq - 0.8).abs() < 1e-12);
        assert!((p.accept_freq - 0.8).abs() < 1e-12);
        assert!((p.target_utilization - 0.7).abs() < 1e-12);
    }

    #[test]
    fn offload_requires_pressure() {
        let p = NodePolicy { offload_freq: 1.0, ..Default::default() };
        let mut rng = Rng::new(0);
        assert!(!p.should_offload(0.1, 0, 0.0, &mut rng));
        assert!(p.should_offload(0.9, 0, 0.0, &mut rng));
        assert!(p.should_offload(0.1, 10, 0.0, &mut rng));
    }

    #[test]
    fn offload_frequency_respected() {
        let p = NodePolicy { offload_freq: 0.25, ..Default::default() };
        let mut rng = Rng::new(1);
        let n = 100_000;
        let hits = (0..n)
            .filter(|_| p.should_offload(1.0, 100, 0.0, &mut rng))
            .count();
        let f = hits as f64 / n as f64;
        assert!((f - 0.25).abs() < 0.01, "f={f}");
    }

    #[test]
    fn latency_penalty_damps_offload() {
        // p = 20/s, nearest candidate 0.1 s away -> damp = 1/3, so the
        // effective offload frequency drops from 0.9 to 0.3.
        let p = NodePolicy {
            offload_freq: 0.9,
            latency_penalty: 20.0,
            ..Default::default()
        };
        let mut rng = Rng::new(2);
        let n = 100_000;
        let hits = (0..n)
            .filter(|_| p.should_offload(1.0, 100, 0.1, &mut rng))
            .count();
        let f = hits as f64 / n as f64;
        assert!((f - 0.3).abs() < 0.01, "f={f}");
        // Zero penalty ignores distance entirely.
        let blind = NodePolicy { offload_freq: 0.9, ..Default::default() };
        let hits = (0..n)
            .filter(|_| blind.should_offload(1.0, 100, 10.0, &mut rng))
            .count();
        let f = hits as f64 / n as f64;
        assert!((f - 0.9).abs() < 0.01, "f={f}");
    }

    #[test]
    fn accept_requires_capacity() {
        let p = NodePolicy { accept_freq: 1.0, ..Default::default() };
        let mut rng = Rng::new(2);
        assert!(p.should_accept(0.5, 0, &mut rng));
        assert!(!p.should_accept(1.0, 0, &mut rng));
        assert!(!p.should_accept(0.5, 100, &mut rng));
    }

    #[test]
    fn requester_only_never_accepts_always_offloads() {
        let p = NodePolicy::requester_only();
        let mut rng = Rng::new(3);
        assert!(p.should_offload(0.0, 0, 0.0, &mut rng));
        assert!(!p.should_accept(0.0, 0, &mut rng));
    }

    #[test]
    fn duel_overhead_formula() {
        let s = SystemPolicy { duel_rate: 0.1, judges: 2, ..Default::default() };
        assert!((s.duel_overhead_factor() - 0.3).abs() < 1e-12);
    }
}
