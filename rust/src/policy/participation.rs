//! Pluggable participation policies — the paper's "participants flexibly
//! determine their participation policies and resource commitments" made a
//! first-class seam.
//!
//! [`NodePolicy`] keeps the scalar knobs (stake, frequencies, thresholds);
//! a [`ParticipationPolicy`] decides *how* those knobs are used at the
//! dispatch boundary:
//!
//! * **offload-or-serve** — given local pressure and the distance to the
//!   nearest live candidate, does a user request enter the delegation
//!   market or the local backend?
//! * **accept-or-reject** — given an incoming probe (who is asking, how big
//!   the job is, how loaded we are), do we take the work?
//! * **candidate scoring** — the per-candidate weight multiplier applied on
//!   top of stake when the delegation snapshot is built.
//! * **maintenance gates** — whether the node tops its stake back up and
//!   whether it re-dispatches queued work when overloaded.
//!
//! [`DefaultPolicy`] reproduces the pre-trait behaviour bit-for-bit (it
//! delegates every decision to the `NodePolicy` methods, including their
//! RNG-draw discipline), so installing it is a no-op — the
//! replay-equivalence test (`rust/tests/replay_equivalence.rs`) pins that.
//! [`RequesterOnly`] replaces the special-cased `NodePolicy::requester_only`
//! branches with a policy object; [`GreedyLocal`] and [`SelectiveAcceptor`]
//! are genuinely new behaviours. Scenario configs select per fleet group
//! via the declarative `topology.fleet` `policy` key (see `config`);
//! [`ParticipationKind`] is the parse/build bridge.

use super::NodePolicy;
use crate::types::NodeId;
use crate::util::rng::Rng;

/// Everything the offload-or-serve decision can see.
#[derive(Debug, Clone, Copy)]
pub struct OffloadCtx {
    /// Local backend running-slot utilization in [0, 1].
    pub utilization: f64,
    /// Requests waiting locally for a slot.
    pub queue_len: usize,
    /// Live latency estimate to the nearest live delegation candidate
    /// (0.0 in flat worlds / region-blind nodes). The no-live-peer case
    /// never reaches the policy — the dispatcher serves locally outright.
    pub nearest_latency: f64,
}

/// Everything the accept-or-reject decision can see about a probe.
#[derive(Debug, Clone, Copy)]
pub struct ProbeCtx {
    /// Who is asking.
    pub from: NodeId,
    pub prompt_tokens: u32,
    pub output_tokens: u32,
    /// Local backend running-slot utilization in [0, 1].
    pub utilization: f64,
    /// Requests waiting locally for a slot.
    pub queue_len: usize,
}

/// A provider's participation behaviour at the dispatch boundary. Every
/// method receives the node's scalar [`NodePolicy`] knobs; implementations
/// interpret (or ignore) them.
///
/// RNG discipline: implementations must draw from `rng` *only* on paths
/// that genuinely need randomness, and deterministically given the inputs —
/// the simulator replays bit-identically from the seed, and the default
/// implementations are draw-for-draw identical to the pre-trait code.
pub trait ParticipationPolicy: std::fmt::Debug {
    /// Stable name for config selection and per-group reporting.
    fn name(&self) -> &'static str;

    /// Should this node try to offload a user request right now (vs.
    /// putting it on the local backend)?
    fn should_offload(
        &self,
        p: &NodePolicy,
        ctx: &OffloadCtx,
        rng: &mut Rng,
    ) -> bool;

    /// Should this node accept a delegated request it was probed for?
    fn accept_probe(
        &self,
        p: &NodePolicy,
        ctx: &ProbeCtx,
        rng: &mut Rng,
    ) -> bool;

    /// Does this policy reweight delegation candidates at all?
    /// `has_latency` says whether a live latency estimator is installed.
    /// Skipping the pass entirely (pure stake-proportional sampling) keeps
    /// flat worlds off the per-candidate scoring loop.
    fn scores_candidates(&self, p: &NodePolicy, has_latency: bool) -> bool {
        p.latency_penalty > 0.0 && has_latency
    }

    /// Weight multiplier for one delegation candidate, given the live
    /// one-way latency estimate to it. Applied on top of stake; 0 removes
    /// the candidate. Only called when [`scores_candidates`] said yes.
    ///
    /// [`scores_candidates`]: ParticipationPolicy::scores_candidates
    fn candidate_weight(&self, p: &NodePolicy, latency: f64) -> f64 {
        1.0 / (1.0 + p.latency_penalty * latency)
    }

    /// Does this node top its stake back up to `p.stake` after slashes?
    fn maintains_stake(&self, p: &NodePolicy) -> bool {
        !p.requester_only
    }

    /// Does this node pull queued work back out of an overloaded backend
    /// and re-dispatch it through the market?
    fn rebalances_queue(&self, p: &NodePolicy) -> bool {
        !p.requester_only
    }

    // --- Byzantine behaviour hooks (see `policy::byzantine`) -------------
    //
    // Honest policies keep every default below; the defaults are RNG-free
    // and behaviour-neutral, so adding them changed no replay stream.

    /// Does this node actually execute and return delegated work it
    /// accepted? `false` models the free-rider: the delegation is
    /// swallowed at admission and the requester discovers the theft only
    /// via its response timeout.
    fn delivers_responses(&self) -> bool {
        true
    }

    /// Multiplier on the backend's intrinsic quality for *delegated* work
    /// (1.0 = honest). A result-faker serves junk to outsiders while its
    /// own users get full quality.
    fn quality_factor(&self) -> f64 {
        1.0
    }

    /// Does this node sign truthful receipts over the work it returns?
    /// `false` forges the response digest, which receipt verification at
    /// settlement catches.
    fn honest_receipts(&self) -> bool {
        true
    }

    /// Mutate the outgoing gossiped RTT rows (the latency-liar hook;
    /// honest nodes leave them untouched).
    fn corrupt_rtts(&self, _rtts: &mut Vec<(u32, u32, f64)>) {}

    /// Mutate the outgoing gossiped reputation rows (the colluder's
    /// slander hook; honest nodes leave them untouched).
    fn corrupt_rep(&self, _rep: &mut Vec<(u32, u32)>) {}
}

/// The pre-trait behaviour: every decision delegates to the corresponding
/// `NodePolicy` method (including the `requester_only` scalar-knob special
/// cases), draw-for-draw.
#[derive(Debug, Clone, Copy, Default)]
pub struct DefaultPolicy;

impl ParticipationPolicy for DefaultPolicy {
    fn name(&self) -> &'static str {
        "default"
    }

    fn should_offload(
        &self,
        p: &NodePolicy,
        ctx: &OffloadCtx,
        rng: &mut Rng,
    ) -> bool {
        p.should_offload(ctx.utilization, ctx.queue_len, ctx.nearest_latency, rng)
    }

    fn accept_probe(
        &self,
        p: &NodePolicy,
        ctx: &ProbeCtx,
        rng: &mut Rng,
    ) -> bool {
        p.should_accept(ctx.utilization, ctx.queue_len, rng)
    }
}

/// A pure consumer: every user request enters the market, no delegated
/// work is ever accepted, no stake is maintained and no queue rebalancing
/// runs. The policy-object form of `NodePolicy::requester_only()` — the
/// replay-equivalence test proves the two are bit-identical.
#[derive(Debug, Clone, Copy, Default)]
pub struct RequesterOnly;

impl ParticipationPolicy for RequesterOnly {
    fn name(&self) -> &'static str {
        "requester_only"
    }

    fn should_offload(&self, _: &NodePolicy, _: &OffloadCtx, _: &mut Rng) -> bool {
        true
    }

    fn accept_probe(&self, _: &NodePolicy, _: &ProbeCtx, _: &mut Rng) -> bool {
        false
    }

    fn maintains_stake(&self, _: &NodePolicy) -> bool {
        false
    }

    fn rebalances_queue(&self, _: &NodePolicy) -> bool {
        false
    }
}

/// A sink: serves its own users strictly locally (never offloads, never
/// rebalances) while greedily accepting delegated work — the
/// `accept_freq` roll is skipped entirely, so acceptance is deterministic
/// given capacity (a running slot free and the queue within
/// `queue_threshold`). Models the provider that monetizes every spare
/// cycle but refuses WAN round trips for its own traffic.
#[derive(Debug, Clone, Copy, Default)]
pub struct GreedyLocal;

impl ParticipationPolicy for GreedyLocal {
    fn name(&self) -> &'static str {
        "greedy_local"
    }

    fn should_offload(&self, _: &NodePolicy, _: &OffloadCtx, _: &mut Rng) -> bool {
        false
    }

    fn accept_probe(&self, p: &NodePolicy, ctx: &ProbeCtx, _: &mut Rng) -> bool {
        ctx.utilization < 1.0 && ctx.queue_len <= p.queue_threshold
    }

    fn rebalances_queue(&self, _: &NodePolicy) -> bool {
        false
    }
}

/// A picky provider: accepts only short jobs, only while comfortably idle,
/// and only with an empty queue — it protects its own users' latency and
/// cherry-picks quick delegated wins. Offload behaviour stays the default.
#[derive(Debug, Clone, Copy)]
pub struct SelectiveAcceptor {
    /// Largest delegated output it will take.
    pub max_output_tokens: u32,
    /// Utilization ceiling for accepting (strictly below the usual
    /// capacity bound of 1.0).
    pub max_utilization: f64,
}

impl Default for SelectiveAcceptor {
    fn default() -> Self {
        SelectiveAcceptor { max_output_tokens: 600, max_utilization: 0.5 }
    }
}

impl ParticipationPolicy for SelectiveAcceptor {
    fn name(&self) -> &'static str {
        "selective"
    }

    fn should_offload(
        &self,
        p: &NodePolicy,
        ctx: &OffloadCtx,
        rng: &mut Rng,
    ) -> bool {
        p.should_offload(ctx.utilization, ctx.queue_len, ctx.nearest_latency, rng)
    }

    fn accept_probe(&self, _: &NodePolicy, ctx: &ProbeCtx, _: &mut Rng) -> bool {
        ctx.output_tokens <= self.max_output_tokens
            && ctx.utilization <= self.max_utilization
            && ctx.queue_len == 0
    }
}

/// Declarative selector for the built-in policies — what the config
/// layer's `policy` / `participation` keys parse into, and what
/// `sim::NodeSetup` carries (the trait object itself is not `Clone`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ParticipationKind {
    #[default]
    Default,
    RequesterOnly,
    GreedyLocal,
    Selective,
}

impl ParticipationKind {
    /// Parse a config-file name. `None` for unknown names — the config
    /// layer turns that into a loud error.
    pub fn parse(s: &str) -> Option<ParticipationKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "default" => ParticipationKind::Default,
            "requester_only" => ParticipationKind::RequesterOnly,
            "greedy_local" => ParticipationKind::GreedyLocal,
            "selective" => ParticipationKind::Selective,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            ParticipationKind::Default => "default",
            ParticipationKind::RequesterOnly => "requester_only",
            ParticipationKind::GreedyLocal => "greedy_local",
            ParticipationKind::Selective => "selective",
        }
    }

    /// Instantiate the policy object.
    pub fn build(self) -> Box<dyn ParticipationPolicy> {
        match self {
            ParticipationKind::Default => Box::new(DefaultPolicy),
            ParticipationKind::RequesterOnly => Box::new(RequesterOnly),
            ParticipationKind::GreedyLocal => Box::new(GreedyLocal),
            ParticipationKind::Selective => {
                Box::new(SelectiveAcceptor::default())
            }
        }
    }

    /// The `NodePolicy` scalar-knob defaults that make sense for this
    /// participation style — the base the config layer fills unspecified
    /// keys from, so `"policy": "requester_only"` groups get stake 0 /
    /// accept 0 without spelling it out.
    pub fn base_policy(self) -> NodePolicy {
        match self {
            ParticipationKind::RequesterOnly => NodePolicy::requester_only(),
            _ => NodePolicy::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn off(util: f64, qlen: usize, near: f64) -> OffloadCtx {
        OffloadCtx { utilization: util, queue_len: qlen, nearest_latency: near }
    }

    fn probe(out_tokens: u32, util: f64, qlen: usize) -> ProbeCtx {
        ProbeCtx {
            from: NodeId(7),
            prompt_tokens: 100,
            output_tokens: out_tokens,
            utilization: util,
            queue_len: qlen,
        }
    }

    #[test]
    fn default_policy_delegates_to_node_policy_knobs() {
        let dp = DefaultPolicy;
        let p = NodePolicy { offload_freq: 1.0, accept_freq: 1.0, ..Default::default() };
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        // Draw-for-draw identical to the scalar-knob methods on the same
        // RNG stream (the refactor's bit-compat contract).
        for i in 0..200 {
            let util = (i % 10) as f64 / 10.0;
            let qlen = i % 7;
            assert_eq!(
                dp.should_offload(&p, &off(util, qlen, 0.01), &mut a),
                p.should_offload(util, qlen, 0.01, &mut b),
                "offload diverged at {i}"
            );
            assert_eq!(
                dp.accept_probe(&p, &probe(500, util, qlen), &mut a),
                p.should_accept(util, qlen, &mut b),
                "accept diverged at {i}"
            );
        }
        assert_eq!(a.next_u64(), b.next_u64(), "RNG streams diverged");
    }

    #[test]
    fn requester_only_constant_decisions_no_draws() {
        let r = RequesterOnly;
        let p = NodePolicy::requester_only();
        let mut rng = Rng::new(2);
        let before = rng.next_u64();
        let mut rng = Rng::new(2);
        assert!(r.should_offload(&p, &off(0.0, 0, 5.0), &mut rng));
        assert!(!r.accept_probe(&p, &probe(1, 0.0, 0), &mut rng));
        assert!(!r.maintains_stake(&p));
        assert!(!r.rebalances_queue(&p));
        // No RNG consumed by either decision.
        assert_eq!(rng.next_u64(), before);
    }

    #[test]
    fn greedy_local_never_offloads_accepts_with_capacity() {
        let g = GreedyLocal;
        let p = NodePolicy { accept_freq: 0.0, ..Default::default() };
        let mut rng = Rng::new(3);
        assert!(!g.should_offload(&p, &off(1.0, 100, 0.0), &mut rng));
        // Ignores accept_freq = 0: capacity is the only criterion.
        assert!(g.accept_probe(&p, &probe(5000, 0.9, p.queue_threshold), &mut rng));
        assert!(!g.accept_probe(&p, &probe(10, 1.0, 0), &mut rng));
        assert!(!g.accept_probe(&p, &probe(10, 0.1, p.queue_threshold + 1), &mut rng));
        assert!(!g.rebalances_queue(&p));
        assert!(g.maintains_stake(&p));
    }

    #[test]
    fn selective_accepts_only_short_jobs_when_idle() {
        let s = SelectiveAcceptor::default();
        let p = NodePolicy::default();
        let mut rng = Rng::new(4);
        assert!(s.accept_probe(&p, &probe(600, 0.4, 0), &mut rng));
        assert!(!s.accept_probe(&p, &probe(601, 0.4, 0), &mut rng), "too long");
        assert!(!s.accept_probe(&p, &probe(100, 0.6, 0), &mut rng), "too busy");
        assert!(!s.accept_probe(&p, &probe(100, 0.1, 1), &mut rng), "queued");
        // Offload side inherits the default knob behaviour.
        let hot = NodePolicy { offload_freq: 1.0, ..Default::default() };
        assert!(s.should_offload(&hot, &off(1.0, 100, 0.0), &mut rng));
    }

    #[test]
    fn default_scoring_matches_latency_damping_formula() {
        let dp = DefaultPolicy;
        let p = NodePolicy { latency_penalty: 50.0, ..Default::default() };
        assert!(dp.scores_candidates(&p, true));
        assert!(!dp.scores_candidates(&p, false), "no estimator, no scoring");
        let blind = NodePolicy::default();
        assert!(!dp.scores_candidates(&blind, true), "zero penalty skips");
        assert!((dp.candidate_weight(&p, 0.1) - 1.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn kind_parses_builds_and_bases() {
        for (name, kind) in [
            ("default", ParticipationKind::Default),
            ("requester_only", ParticipationKind::RequesterOnly),
            ("greedy_local", ParticipationKind::GreedyLocal),
            ("selective", ParticipationKind::Selective),
        ] {
            assert_eq!(ParticipationKind::parse(name), Some(kind));
            assert_eq!(kind.name(), name);
            assert_eq!(kind.build().name(), name);
        }
        assert_eq!(ParticipationKind::parse("DEFAULT"), Some(ParticipationKind::Default));
        assert!(ParticipationKind::parse("freeloader").is_none());
        assert!(ParticipationKind::RequesterOnly.base_policy().requester_only);
        assert_eq!(ParticipationKind::RequesterOnly.base_policy().stake, 0);
        assert!(!ParticipationKind::GreedyLocal.base_policy().requester_only);
    }
}
