//! Proof-of-Stake executor selection (§3.2, §4.1).
//!
//! A delegating node samples executor candidates with probability
//! proportional to staked credit, restricted to peers its gossip view
//! believes are online. Two sampling strategies:
//!
//! * linear scan over the stake vector — O(n) per sample, zero setup;
//! * alias table — O(n) build, O(1) sample, amortized over many samples from
//!   the same stake snapshot (the hot-path choice; crossover measured in
//!   `benches/micro.rs`).

use crate::types::{Credits, NodeId};
use crate::util::rng::{AliasTable, Rng};

/// A snapshot of eligible executors and their stakes.
#[derive(Debug, Clone)]
pub struct StakeSnapshot {
    nodes: Vec<NodeId>,
    stakes: Vec<f64>,
    alias: Option<AliasTable>,
}

impl StakeSnapshot {
    /// Build from (node, stake) pairs, excluding `me` (a node never delegates
    /// to itself) and anything with zero stake.
    pub fn new(stakes: &[(NodeId, Credits)], exclude: Option<NodeId>) -> Self {
        let mut nodes = Vec::with_capacity(stakes.len());
        let mut weights = Vec::with_capacity(stakes.len());
        for (n, s) in stakes {
            if Some(*n) == exclude || *s == 0 {
                continue;
            }
            nodes.push(*n);
            weights.push(*s as f64);
        }
        StakeSnapshot { nodes, stakes: weights, alias: None }
    }

    /// Restrict to nodes satisfying `alive` (the gossip view's liveness).
    pub fn retain(&mut self, alive: impl Fn(NodeId) -> bool) {
        let mut nodes = Vec::with_capacity(self.nodes.len());
        let mut stakes = Vec::with_capacity(self.stakes.len());
        for (n, s) in self.nodes.iter().zip(&self.stakes) {
            if alive(*n) {
                nodes.push(*n);
                stakes.push(*s);
            }
        }
        self.nodes = nodes;
        self.stakes = stakes;
        self.alias = None;
    }

    /// Scale each candidate's weight by `factor(node)` (locality-aware
    /// dispatch multiplies stake by a latency damping term). Factors must be
    /// non-negative; a zero factor removes the candidate from selection.
    pub fn reweight(&mut self, factor: impl Fn(NodeId) -> f64) {
        for (n, w) in self.nodes.iter().zip(self.stakes.iter_mut()) {
            *w *= factor(*n).max(0.0);
        }
        self.alias = None;
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn nodes(&self) -> &[NodeId] {
        &self.nodes
    }

    /// Precompute the alias table for O(1) sampling.
    pub fn prepare(&mut self) {
        if self.alias.is_none() {
            self.alias = AliasTable::new(&self.stakes);
        }
    }

    /// One stake-proportional draw. Uses the alias table if prepared.
    pub fn sample(&self, rng: &mut Rng) -> Option<NodeId> {
        if let Some(t) = &self.alias {
            return Some(self.nodes[t.sample(rng)]);
        }
        rng.weighted(&self.stakes).map(|i| self.nodes[i])
    }

    /// Linear-scan draw regardless of alias state (for benchmarking).
    pub fn sample_linear(&self, rng: &mut Rng) -> Option<NodeId> {
        rng.weighted(&self.stakes).map(|i| self.nodes[i])
    }

    /// Draw k *distinct* nodes, stake-proportional without replacement
    /// (duel executors, judge committees). Falls back to fewer if the pool
    /// is small.
    pub fn sample_distinct(&self, rng: &mut Rng, k: usize) -> Vec<NodeId> {
        let mut weights = self.stakes.clone();
        let mut out = Vec::with_capacity(k);
        for _ in 0..k.min(self.nodes.len()) {
            match rng.weighted(&weights) {
                Some(i) => {
                    out.push(self.nodes[i]);
                    weights[i] = 0.0;
                }
                None => break,
            }
        }
        out
    }

    /// Selection probability of `node` in this snapshot (p_i of §5).
    pub fn probability(&self, node: NodeId) -> f64 {
        let total: f64 = self.stakes.iter().sum();
        if total <= 0.0 {
            return 0.0;
        }
        self.nodes
            .iter()
            .zip(&self.stakes)
            .find(|(n, _)| **n == node)
            .map(|(_, s)| s / total)
            .unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snapshot() -> StakeSnapshot {
        StakeSnapshot::new(
            &[
                (NodeId(0), 100),
                (NodeId(1), 200),
                (NodeId(2), 300),
                (NodeId(3), 0),
            ],
            None,
        )
    }

    #[test]
    fn excludes_self_and_zero() {
        let s = StakeSnapshot::new(
            &[(NodeId(0), 100), (NodeId(1), 200), (NodeId(2), 0)],
            Some(NodeId(0)),
        );
        assert_eq!(s.nodes(), &[NodeId(1)]);
    }

    #[test]
    fn sampling_proportional() {
        let mut s = snapshot();
        s.prepare();
        let mut rng = Rng::new(1);
        let mut counts = std::collections::BTreeMap::new();
        let n = 300_000;
        for _ in 0..n {
            *counts.entry(s.sample(&mut rng).unwrap()).or_insert(0usize) += 1;
        }
        assert!(!counts.contains_key(&NodeId(3)));
        let f1 = counts[&NodeId(1)] as f64 / n as f64;
        let f2 = counts[&NodeId(2)] as f64 / n as f64;
        assert!((f1 - 2.0 / 6.0).abs() < 0.01, "f1={f1}");
        assert!((f2 - 0.5).abs() < 0.01, "f2={f2}");
    }

    #[test]
    fn linear_and_alias_agree_statistically() {
        let mut s = snapshot();
        let mut rng = Rng::new(2);
        let n = 200_000;
        let mut lin = 0usize;
        for _ in 0..n {
            if s.sample_linear(&mut rng) == Some(NodeId(2)) {
                lin += 1;
            }
        }
        s.prepare();
        let mut ali = 0usize;
        for _ in 0..n {
            if s.sample(&mut rng) == Some(NodeId(2)) {
                ali += 1;
            }
        }
        let d = (lin as f64 - ali as f64).abs() / n as f64;
        assert!(d < 0.01, "methods diverge: {d}");
    }

    #[test]
    fn retain_filters_dead_nodes() {
        let mut s = snapshot();
        s.retain(|n| n != NodeId(2));
        assert_eq!(s.nodes(), &[NodeId(0), NodeId(1)]);
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            assert_ne!(s.sample(&mut rng), Some(NodeId(2)));
        }
    }

    #[test]
    fn distinct_sampling_unique_and_proportionalish() {
        let s = snapshot();
        let mut rng = Rng::new(4);
        for _ in 0..500 {
            let picks = s.sample_distinct(&mut rng, 2);
            assert_eq!(picks.len(), 2);
            assert_ne!(picks[0], picks[1]);
        }
        // Ask for more than available.
        assert_eq!(s.sample_distinct(&mut rng, 10).len(), 3);
    }

    #[test]
    fn probability_matches_definition() {
        let s = snapshot();
        assert!((s.probability(NodeId(0)) - 100.0 / 600.0).abs() < 1e-12);
        assert!((s.probability(NodeId(2)) - 0.5).abs() < 1e-12);
        assert_eq!(s.probability(NodeId(3)), 0.0);
        assert_eq!(s.probability(NodeId(9)), 0.0);
    }

    #[test]
    fn reweight_shifts_selection_mass() {
        let mut s = snapshot();
        // Damp node 2 (stake 300) by 10x: node 1 (stake 200) now dominates.
        s.reweight(|n| if n == NodeId(2) { 0.1 } else { 1.0 });
        let mut rng = Rng::new(9);
        let n = 200_000;
        let mut c1 = 0usize;
        let mut c2 = 0usize;
        for _ in 0..n {
            match s.sample(&mut rng) {
                Some(NodeId(1)) => c1 += 1,
                Some(NodeId(2)) => c2 += 1,
                _ => {}
            }
        }
        // Weights: 100, 200, 30 -> node 1 at ~0.606, node 2 at ~0.091.
        let f1 = c1 as f64 / n as f64;
        let f2 = c2 as f64 / n as f64;
        assert!((f1 - 200.0 / 330.0).abs() < 0.01, "f1={f1}");
        assert!((f2 - 30.0 / 330.0).abs() < 0.01, "f2={f2}");
        // probability() reflects the damped weights too.
        assert!((s.probability(NodeId(2)) - 30.0 / 330.0).abs() < 1e-12);
    }

    #[test]
    fn empty_pool_returns_none() {
        let s = StakeSnapshot::new(&[], None);
        let mut rng = Rng::new(5);
        assert!(s.is_empty());
        assert_eq!(s.sample(&mut rng), None);
        assert!(s.sample_distinct(&mut rng, 2).is_empty());
    }
}
