//! Figure/table regeneration harnesses — one function per experiment in the
//! paper's evaluation (the index lives in DESIGN.md §4). `examples/
//! reproduce.rs` prints them; `benches/*` time and re-verify them.

use crate::backend::Profile;
use crate::metrics::Recorder;
use crate::policy::{NodePolicy, SystemPolicy};
use crate::schedulers::{self, Strategy};
use crate::sim::{NodeSetup, World, WorldConfig};
use crate::types::{NodeId, Time};
use crate::workload::{Generator, LengthDist, Phase, Setting, SettingId};

/// Time past the schedule end we let a world drain so queued work finishes.
const DRAIN: Time = 4000.0;

// ---------------------------------------------------------------------------
// Figure 4 + Table 2: scheduling efficiency across Settings 1-4
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct SettingRun {
    pub setting: SettingId,
    pub strategy: Strategy,
    pub completed: usize,
    pub slo_attainment: f64,
    /// SLO attainment vs deadline-scale sweep (the Figure-4 curves).
    pub slo_curve: Vec<(f64, f64)>,
    pub mean_latency: f64,
    pub p99_latency: f64,
}

pub const SLO_SCALES: [f64; 7] = [0.25, 0.5, 0.75, 1.0, 1.5, 2.0, 3.0];

fn summarize(
    setting: SettingId,
    strategy: Strategy,
    rec: &Recorder,
) -> SettingRun {
    SettingRun {
        setting,
        strategy,
        completed: rec.user_records().count(),
        slo_attainment: rec.slo_attainment(),
        slo_curve: rec.slo_curve(&SLO_SCALES),
        mean_latency: rec.mean_latency(),
        p99_latency: rec.latency_percentile(0.99).unwrap_or(0.0),
    }
}

/// Run one (setting, strategy) cell of Figure 4 / Table 2.
pub fn run_setting(id: SettingId, strategy: Strategy, seed: u64) -> SettingRun {
    let setting = Setting::get(id);
    let horizon = setting.horizon;
    let profiles: Vec<Profile> =
        setting.nodes.iter().map(|n| n.profile()).collect();
    let generators: Vec<Option<Generator>> = setting
        .nodes
        .iter()
        .enumerate()
        .map(|(i, n)| Some(Generator::new(NodeId(i as u32), n.phases.clone())))
        .collect();

    let rec = match strategy {
        Strategy::Single => {
            schedulers::run_single(profiles, generators, horizon, seed)
        }
        Strategy::Centralized => {
            schedulers::run_centralized(profiles, generators, horizon, seed)
        }
        Strategy::Decentralized => {
            let cfg = WorldConfig { seed, ..Default::default() };
            let setups: Vec<NodeSetup> = profiles
                .iter()
                .zip(generators)
                .map(|(p, g)| {
                    let mut s = NodeSetup::new(*p, NodePolicy::default());
                    if let Some(g) = g {
                        s = s.with_generator(g);
                    }
                    s
                })
                .collect();
            let mut w = World::new(cfg, setups);
            w.run_until(horizon + DRAIN);
            w.recorder
        }
    };
    summarize(id, strategy, &rec)
}

/// The full Figure-4/Table-2 grid.
pub fn fig4_table2(seed: u64) -> Vec<SettingRun> {
    let mut out = Vec::new();
    for id in SettingId::ALL {
        for strategy in
            [Strategy::Single, Strategy::Centralized, Strategy::Decentralized]
        {
            out.push(run_setting(id, strategy, seed));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Figure 5: dynamic participation (joins / leaves)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct DynamicRun {
    /// (window center, mean latency) — the black line of Figure 5.
    pub windowed_latency: Vec<(Time, f64)>,
    /// (time, "join"/"leave") — the blue markers.
    pub events: Vec<(Time, &'static str)>,
    pub completed: usize,
}

fn dynamic_setup(n: usize, offline_after: usize, load_ia: f64, horizon: f64)
    -> Vec<NodeSetup>
{
    (0..n)
        .map(|i| {
            // The two initial nodes provide ~525 tok/s each; the two that
            // join/leave provide ~1050 tok/s each. The 2-node network then
            // runs at rho ~1.8 (queues blow up), the 4-node one at ~0.6
            // (queues drain) — the regimes Figure 5 contrasts.
            let profile = if i < 2 {
                Profile::test(35.0, 30)
            } else {
                Profile::test(35.0, 60)
            };
            let mut s = NodeSetup::new(
                profile,
                NodePolicy { accept_freq: 1.0, ..Default::default() },
            )
            .with_generator(
                Generator::new(
                    NodeId(i as u32),
                    // Only the first two nodes carry user load, so capacity
                    // changes show up directly in their latency.
                    if i < 2 {
                        vec![Phase::new(0.0, horizon, load_ia)]
                    } else {
                        vec![]
                    },
                )
                // Shorter outputs than the Table-3 workloads: queueing
                // transients then play out well within the 750 s horizon,
                // which is what Figure 5 plots.
                .with_lengths(LengthDist {
                    output_mean: 1500.0,
                    output_sigma: 0.5,
                    ..Default::default()
                }),
            );
            if i >= offline_after {
                s = s.offline();
            }
            s
        })
        .collect()
}

/// Figure 5a: start with 2 nodes, two more join at 250 s and 500 s.
pub fn fig5_join(seed: u64) -> DynamicRun {
    let horizon = 750.0;
    // Overloaded duo: inter-arrival 1.6 s each (~940 tok/s demand per
    // node vs ~525 tok/s capacity).
    let setups = dynamic_setup(4, 2, 1.6, horizon);
    let cfg = WorldConfig { seed, ..Default::default() };
    let mut w = World::new(cfg, setups);
    w.schedule_join(2, 250.0);
    w.schedule_join(3, 500.0);
    w.run_until(horizon + DRAIN);
    DynamicRun {
        windowed_latency: w.recorder.windowed_latency(25.0),
        events: vec![(250.0, "join"), (500.0, "join")],
        completed: w.recorder.user_records().count(),
    }
}

/// Figure 5b: start with 4 nodes, two leave at 250 s and 500 s.
pub fn fig5_leave(seed: u64) -> DynamicRun {
    let horizon = 750.0;
    let setups = dynamic_setup(4, 4, 1.6, horizon);
    let cfg = WorldConfig { seed, ..Default::default() };
    let mut w = World::new(cfg, setups);
    w.schedule_leave(3, 250.0);
    w.schedule_leave(2, 500.0);
    w.run_until(horizon + DRAIN);
    DynamicRun {
        windowed_latency: w.recorder.windowed_latency(25.0),
        events: vec![(250.0, "leave"), (500.0, "leave")],
        completed: w.recorder.user_records().count(),
    }
}

// ---------------------------------------------------------------------------
// Figure 6: quality incentivization (credit dynamics)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fig6Variant {
    /// (a) model capacity: Qwen3 8B / 4B / 0.6B.
    ModelCapacity,
    /// (b) quantization: fp8wo / int4wo-128 / int4wo-32.
    Quantization,
    /// (c) serving efficiency: FlashInfer / Triton / SDPA backends.
    ServingEfficiency,
    /// (d) hardware: A100 / RTX4090 / RTX3090.
    Hardware,
}

impl Fig6Variant {
    pub const ALL: [Fig6Variant; 4] = [
        Fig6Variant::ModelCapacity,
        Fig6Variant::Quantization,
        Fig6Variant::ServingEfficiency,
        Fig6Variant::Hardware,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Fig6Variant::ModelCapacity => "model capacity (6a)",
            Fig6Variant::Quantization => "quantization (6b)",
            Fig6Variant::ServingEfficiency => "serving efficiency (6c)",
            Fig6Variant::Hardware => "hardware (6d)",
        }
    }

    /// Three node classes: (label, profile). Two replicas each, per §6.3.
    /// Profiles use the fig6 workload's ~1.2k-token contexts.
    fn classes(self) -> Vec<(&'static str, Profile)> {
        use crate::backend::{Gpu, ModelClass, ServingStack};
        const CTX: f64 = 1200.0;
        let derive = |m, g| Profile::derive_with_ctx(m, g, ServingStack::SgLang, CTX);
        match self {
            // Quality-separated tiers (win rates ≈ 0.57/0.53/0.39).
            Fig6Variant::ModelCapacity => vec![
                ("Qwen3-8B", derive(ModelClass::Qwen3_8B, Gpu::A100)),
                ("Qwen3-4B", derive(ModelClass::Qwen3_4B, Gpu::A100)),
                ("Qwen3-0.6B", derive(ModelClass::Qwen3_0_6B, Gpu::A100)),
            ],
            // Same model, degrading quality + slightly rising speed
            // (win rates ≈ 0.54/0.49/0.47).
            Fig6Variant::Quantization => {
                let base = derive(ModelClass::Qwen3_8B, Gpu::A100);
                vec![
                    ("fp8wo", base.with_quality(0.78)),
                    ("int4wo-128", base.scaled(1.15).with_quality(0.74)),
                    ("int4wo-32", base.scaled(1.20).with_quality(0.71)),
                ]
            }
            // Same quality, different throughput (served 788/786/426).
            Fig6Variant::ServingEfficiency => {
                let base = derive(ModelClass::Qwen3_8B, Gpu::A100);
                vec![
                    ("FlashInfer", base),
                    ("Triton", base.scaled(0.97)),
                    ("SDPA", base.scaled(0.52)),
                ]
            }
            // Same model/quality, different GPUs (served 1717/1195/1088).
            Fig6Variant::Hardware => vec![
                ("A100", derive(ModelClass::Qwen3_8B, Gpu::A100)),
                ("RTX4090", derive(ModelClass::Qwen3_8B, Gpu::Rtx4090)),
                ("RTX3090", derive(ModelClass::Qwen3_8B, Gpu::Rtx3090)),
            ],
        }
    }
}

#[derive(Debug, Clone)]
pub struct Fig6Run {
    pub variant: Fig6Variant,
    /// One entry per class: label, served user requests (summed over the 2
    /// replicas), duel win rate, final credits, credit-over-time curve.
    pub classes: Vec<Fig6Class>,
    pub total_duels: usize,
}

#[derive(Debug, Clone)]
pub struct Fig6Class {
    pub label: String,
    pub served: usize,
    pub win_rate: f64,
    pub final_credits: f64,
    pub credit_curve: Vec<(Time, f64)>,
}

/// One Figure-6 experiment: 3 classes x 2 replicas + a requester-only node
/// flooding the market with delegations; duels redistribute credit.
pub fn fig6(variant: Fig6Variant, seed: u64) -> Fig6Run {
    let classes = variant.classes();
    let horizon = 750.0;
    // Request pressure + economics per variant: the quality experiments
    // (6a/6b) run unsaturated with strong duel stakes, so credit dynamics
    // isolate response quality; the throughput experiments (6c/6d) run at
    // saturation with default duel stakes, so credit dynamics track
    // completed volume (the paper's served counts 788/786/426 and
    // 1717/1195/1088).
    let quality_variant = matches!(
        variant,
        Fig6Variant::ModelCapacity | Fig6Variant::Quantization
    );
    let inter_arrival = match variant {
        Fig6Variant::ModelCapacity | Fig6Variant::Quantization => 1.2,
        Fig6Variant::ServingEfficiency => 0.30,
        Fig6Variant::Hardware => 0.16,
    };
    let mut setups = vec![NodeSetup::new(
        Profile::test(1.0, 1),
        NodePolicy::requester_only(),
    )
    .with_generator(
        Generator::new(NodeId(0), vec![Phase::new(0.0, horizon, inter_arrival)])
            .with_lengths(LengthDist {
                output_mean: 900.0,
                ..Default::default()
            }),
    )];
    for (_, profile) in &classes {
        for _ in 0..2 {
            setups.push(NodeSetup::new(
                *profile,
                NodePolicy { accept_freq: 1.0, ..Default::default() },
            ));
        }
    }
    let cfg = WorldConfig {
        seed,
        system: if quality_variant {
            SystemPolicy {
                duel_rate: 0.25,
                duel_reward: 2 * crate::types::CREDIT,
                duel_penalty: 2 * crate::types::CREDIT,
                genesis_credits: 300 * crate::types::CREDIT,
                ..Default::default()
            }
        } else {
            SystemPolicy {
                duel_rate: 0.10,
                // Enough liquidity for the requester to pay ~5k delegations.
                genesis_credits: 1000 * crate::types::CREDIT,
                ..Default::default()
            }
        },
        ..Default::default()
    };
    let mut w = World::new(cfg, setups);
    w.run_until(horizon + DRAIN);

    let served = w.recorder.served_by();
    let mut out = Vec::new();
    for (ci, (label, _)) in classes.iter().enumerate() {
        let ids = [1 + 2 * ci, 2 + 2 * ci]; // replica node indices
        let mut total_served = 0usize;
        let mut wins = 0usize;
        let mut losses = 0usize;
        let mut final_credits = 0.0;
        // Average the two replicas' credit curves.
        let curve_a = &w.credit_series[ids[0]].points;
        let curve_b = &w.credit_series[ids[1]].points;
        // Average the replicas and truncate at the workload horizon (the
        // drain period that lets queues empty is not part of the figure).
        let curve: Vec<(Time, f64)> = curve_a
            .iter()
            .zip(curve_b.iter())
            .filter(|((t, _), _)| *t <= horizon)
            .map(|((t, a), (_, b))| (*t, (a + b) / 2.0))
            .collect();
        for id in ids {
            let nid = NodeId(id as u32);
            total_served += served.get(&nid).copied().unwrap_or(0);
            wins += w.duel_stats.wins.get(&nid).copied().unwrap_or(0);
            losses += w.duel_stats.losses.get(&nid).copied().unwrap_or(0);
            final_credits += w.credit_totals()[id];
        }
        out.push(Fig6Class {
            label: label.to_string(),
            served: total_served,
            win_rate: if wins + losses > 0 {
                wins as f64 / (wins + losses) as f64
            } else {
                0.0
            },
            final_credits,
            credit_curve: curve,
        });
    }
    Fig6Run {
        variant,
        classes: out,
        total_duels: w.duel_stats.total_duels(),
    }
}

// ---------------------------------------------------------------------------
// Figure 7: duel-rate ablation
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig7Run {
    pub duel_rate: f64,
    pub latency_cdf: Vec<(f64, f64)>,
    pub slo_curve: Vec<(f64, f64)>,
    pub mean_latency: f64,
    /// Measured synthetic (duel-copy + judge) executions.
    pub synthetic: usize,
    /// Completed user requests.
    pub completed: usize,
    /// Observed delegation count (for the N·α·p_d·(1+k) formula check).
    pub delegated: u64,
}

/// §7.1 setup: 4 serving nodes, k=2 judges, uniform requester-only load.
pub fn fig7(duel_rate: f64, seed: u64) -> Fig7Run {
    let horizon = 750.0;
    let mut setups = vec![NodeSetup::new(
        Profile::test(1.0, 1),
        NodePolicy::requester_only(),
    )
    .with_generator(
        Generator::new(NodeId(0), vec![Phase::new(0.0, horizon, 1.2)])
            .with_lengths(LengthDist { output_mean: 900.0, ..Default::default() }),
    )];
    for _ in 0..4 {
        setups.push(NodeSetup::new(
            Profile::test(40.0, 24),
            NodePolicy { accept_freq: 1.0, ..Default::default() },
        ));
    }
    let cfg = WorldConfig {
        seed,
        system: SystemPolicy { duel_rate, judges: 2, ..Default::default() },
        ..Default::default()
    };
    let mut w = World::new(cfg, setups);
    w.run_until(horizon + DRAIN);

    let cdf_pts: Vec<f64> = (0..40).map(|i| i as f64 * 10.0).collect();
    Fig7Run {
        duel_rate,
        latency_cdf: w.recorder.latency_cdf(&cdf_pts),
        slo_curve: w.recorder.slo_curve(&SLO_SCALES),
        mean_latency: w.recorder.mean_latency(),
        synthetic: w.recorder.synthetic_count(),
        completed: w.recorder.user_records().count(),
        delegated: w.node(0).stats.delegated_out,
    }
}

// ---------------------------------------------------------------------------
// Figure 8: user-level policy ablations
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig8aRun {
    /// Per serving node: (stake in credits, served requests, share).
    pub rows: Vec<(f64, usize, f64)>,
}

/// Figure 8a/8b helper: requester floods, 4 servers differ in one knob.
fn fig8_serving_split(
    policies: Vec<NodePolicy>,
    seed: u64,
) -> Vec<usize> {
    let horizon = 750.0;
    let mut setups = vec![NodeSetup::new(
        Profile::test(1.0, 1),
        NodePolicy::requester_only(),
    )
    .with_generator(
        Generator::new(NodeId(0), vec![Phase::new(0.0, horizon, 1.0)])
            .with_lengths(LengthDist { output_mean: 900.0, ..Default::default() }),
    )];
    for p in policies {
        setups.push(NodeSetup::new(Profile::test(40.0, 32), p));
    }
    let cfg = WorldConfig {
        seed,
        system: SystemPolicy { duel_rate: 0.0, ..Default::default() },
        ..Default::default()
    };
    let mut w = World::new(cfg, setups);
    w.run_until(horizon + DRAIN);
    let served = w.recorder.served_by();
    (1..=4)
        .map(|i| served.get(&NodeId(i as u32)).copied().unwrap_or(0))
        .collect()
}

/// Figure 8a: stakes 1/2/3/4 → delegated share ∝ stake.
pub fn fig8a(seed: u64) -> Fig8aRun {
    use crate::types::CREDIT;
    let stakes = [1u64, 2, 3, 4];
    let policies = stakes
        .iter()
        .map(|s| NodePolicy {
            stake: s * CREDIT,
            accept_freq: 1.0,
            ..Default::default()
        })
        .collect();
    let served = fig8_serving_split(policies, seed);
    let total: usize = served.iter().sum();
    Fig8aRun {
        rows: stakes
            .iter()
            .zip(&served)
            .map(|(s, n)| {
                (*s as f64, *n, *n as f64 / total.max(1) as f64)
            })
            .collect(),
    }
}

/// Figure 8b: acceptance frequencies 0.25/0.5/0.75/1.0.
pub fn fig8b(seed: u64) -> Fig8aRun {
    let freqs = [0.25, 0.5, 0.75, 1.0];
    let policies = freqs
        .iter()
        .map(|f| NodePolicy { accept_freq: *f, ..Default::default() })
        .collect();
    let served = fig8_serving_split(policies, seed);
    let total: usize = served.iter().sum();
    Fig8aRun {
        rows: freqs
            .iter()
            .zip(&served)
            .map(|(f, n)| (*f, *n, *n as f64 / total.max(1) as f64))
            .collect(),
    }
}

#[derive(Debug, Clone)]
pub struct Fig8cRun {
    /// (offload_freq, slo attainment, mean latency)
    pub rows: Vec<(f64, f64, f64)>,
}

/// Figure 8c: offload frequency sweep under sustained pressure; all four
/// nodes carry heavy load and share one offload knob per run.
pub fn fig8c(seed: u64) -> Fig8cRun {
    let horizon = 750.0;
    let mut rows = Vec::new();
    for freq in [0.25, 0.5, 0.75, 1.0] {
        // Two hot nodes (locally overloaded, rho ~1.6) + two cold nodes;
        // the network as a whole runs at rho ~0.85, so offloading is what
        // decides whether deadlines are met.
        let mut setups = Vec::new();
        for i in 0..4 {
            let phases = if i < 2 {
                vec![Phase::new(0.0, horizon, 2.2)]
            } else {
                vec![Phase::new(0.0, horizon, 30.0)]
            };
            setups.push(
                NodeSetup::new(
                    Profile::test(35.0, 24),
                    NodePolicy {
                        offload_freq: freq,
                        accept_freq: 1.0,
                        ..Default::default()
                    },
                )
                .with_generator(
                    Generator::new(NodeId(i as u32), phases).with_lengths(
                        LengthDist {
                            output_mean: 1500.0,
                            output_sigma: 0.5,
                            ..Default::default()
                        },
                    ),
                ),
            );
        }
        let cfg = WorldConfig {
            seed,
            system: SystemPolicy { duel_rate: 0.0, ..Default::default() },
            ..Default::default()
        };
        let mut w = World::new(cfg, setups);
        w.run_until(horizon + DRAIN);
        rows.push((freq, w.recorder.slo_attainment(), w.recorder.mean_latency()));
    }
    Fig8cRun { rows }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Heavier repro sanity is covered by benches + integration tests; here
    // just pin cheap invariants.

    #[test]
    fn fig7_overhead_formula_holds() {
        let r = fig7(0.25, 3);
        assert!(r.completed > 100);
        // Expected synthetics = delegated * p_d * (1 + k). Duels that fell
        // back (no judges) and timing edges add noise: allow 40% rel err.
        let expected = r.delegated as f64 * 0.25 * 3.0;
        let got = r.synthetic as f64;
        assert!(
            (got - expected).abs() / expected.max(1.0) < 0.4,
            "synthetic={got} expected≈{expected}"
        );
    }

    #[test]
    fn fig8a_share_increases_with_stake() {
        let r = fig8a(5);
        let shares: Vec<f64> = r.rows.iter().map(|(_, _, s)| *s).collect();
        assert!(
            shares[3] > shares[0],
            "stake-4 node should out-serve stake-1: {shares:?}"
        );
    }
}
