//! Per-peer reputation + the defense configuration against Byzantine
//! participants.
//!
//! ## Threat model
//!
//! The network's premise — every provider freely chooses its
//! participation policy — includes providers that misbehave. The attacker
//! personalities live in `policy/byzantine.rs` as ordinary
//! [`ParticipationPolicy`] implementations; each is countered by a
//! specific defense wired through the coordinator:
//!
//! | attacker      | behaviour                                   | caught by |
//! |---------------|---------------------------------------------|-----------|
//! | `FreeRider`   | accepts delegations, silently drops them    | delegation timeouts feed [`RepEvent::Timeout`]; repeat offenders fall under the quarantine threshold and stop being sampled |
//! | `ResultFaker` | returns junk answers, forges receipt digests| receipt verification at settlement (`RepEvent::ReceiptFail`, work never paid) + duel losses ([`RepEvent::DuelLoss`]) |
//! | `LatencyLiar` | poisons piggybacked RTT rows in gossip      | hearsay capping in `coordinator/latency_feed.rs`: a gossiped cell can never move more than [`DefenseConfig::hearsay_cap`]× away from the node's own expectation |
//! | `Colluder`    | faker quality + slanders honest peers in gossiped reputation rows | remote opinions are influence-bounded: hearsay alone scales an honest score by at most `0.5 + 0.5·remote ≥ 0.5`, which cannot cross the default quarantine threshold without own-evidence corroboration |
//!
//! **Out of scope:** Sybil identities (node ids are fixed at world build;
//! key distribution is assumed honest), collusion majorities among judges
//! (quorum sampling assumes an honest supermajority of stake, the paper's
//! Assumption 5.2), and duel-settlement receipt gating (duel responses
//! with bad receipts are rejected at ingest, but the duel reward path
//! itself still settles on judge verdicts alone).
//!
//! ## Reputation model
//!
//! [`ReputationBook`] is deterministic and RNG-free. Each peer has an
//! **own-evidence score** in `[0, 1]` (default 1.0) driven by events this
//! node observed first-hand: multiplicative penalties for timeouts,
//! receipt failures and duel losses; bounded recovery on verified
//! successes; and a slow linear time-heal so a transiently faulty peer is
//! eventually re-tried. A **remote opinion** merged from gossiped
//! reputation rows ([`ReputationBook::rep_rows`]) modulates the own score
//! with bounded influence: `effective = own · (0.5 + 0.5 · remote)`.
//! Dispatch down-weights candidates by `effective`, and past
//! [`DefenseConfig::quarantine_threshold`] the peer is quarantined out of
//! the candidate set entirely (released with hysteresis once it heals).
//!
//! With `defenses.enabled = false` (the default) nothing in this module
//! is consulted: no receipts on the wire, no reputation rows in gossip,
//! no extra RNG draws — replay fingerprints stay bit-identical to the
//! defenseless baseline (pinned in `rust/tests/replay_equivalence.rs`).
//!
//! [`ParticipationPolicy`]: crate::policy::ParticipationPolicy

use std::collections::{BTreeMap, BTreeSet};

use crate::crypto::{KeyStore, NodeKey};
use crate::types::{NodeId, Time};

/// Reputation rows piggybacked on gossip deltas: `(node, milli-score in
/// 0..=1000)` pairs of peers the sender distrusts from its own evidence.
pub type RepRows = Vec<(u32, u32)>;

/// Declarative `defenses` config block knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DefenseConfig {
    /// Master switch. `false` (the default) makes every defense hook a
    /// no-op and keeps the wire format byte-identical to the defenseless
    /// network.
    pub enabled: bool,
    /// Verify signed work receipts at settlement; unreceipted or
    /// mis-signed delegated work is never paid.
    pub receipts: bool,
    /// Track per-peer reputation, gossip it, and gate dispatch on it.
    pub reputation: bool,
    /// Effective score below which a peer is quarantined out of the
    /// dispatch candidate set (released above 1.5× with hysteresis).
    pub quarantine_threshold: f64,
    /// Bound on gossiped RTT hearsay: a merged cell value is clamped to
    /// within this factor of the estimator's own expectation for the cell.
    pub hearsay_cap: f64,
}

impl Default for DefenseConfig {
    fn default() -> Self {
        DefenseConfig {
            enabled: false,
            receipts: true,
            reputation: true,
            quarantine_threshold: 0.25,
            hearsay_cap: 3.0,
        }
    }
}

impl DefenseConfig {
    /// Validate, returning a descriptive error (the config-parser path).
    pub fn check(&self) -> Result<(), String> {
        if !self.quarantine_threshold.is_finite()
            || !(0.0..1.0).contains(&self.quarantine_threshold)
        {
            return Err(format!(
                "quarantine_threshold must be a finite fraction in [0, 1), \
                 got {}",
                self.quarantine_threshold
            ));
        }
        if !self.hearsay_cap.is_finite() || self.hearsay_cap < 1.0 {
            return Err(format!(
                "hearsay_cap must be a finite factor >= 1, got {}",
                self.hearsay_cap
            ));
        }
        Ok(())
    }

    /// Panicking twin of [`check`](Self::check) for programmatic configs.
    pub fn validate(&self) {
        if let Err(e) = self.check() {
            panic!("DefenseConfig: {e}");
        }
    }
}

/// First-hand evidence about a peer, fed into [`ReputationBook::record`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RepEvent {
    /// A delegated request settled cleanly (receipt verified when on).
    Success,
    /// A delegated request timed out with no response.
    Timeout,
    /// A settlement receipt was missing, mis-signed, or didn't match the
    /// response content.
    ReceiptFail,
    /// The peer won a duel this node originated.
    DuelWin,
    /// The peer lost a duel this node originated.
    DuelLoss,
}

/// Quarantine-state change caused by an update (for span emission).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Transition {
    None,
    Quarantined,
    Released,
}

/// Multiplicative own-score penalty per event (see module docs).
fn penalty(ev: RepEvent) -> Option<f64> {
    match ev {
        RepEvent::Timeout => Some(0.7),
        RepEvent::ReceiptFail => Some(0.4),
        RepEvent::DuelLoss => Some(0.6),
        RepEvent::Success | RepEvent::DuelWin => None,
    }
}

/// Recovery step toward 1.0 on positive events.
const RECOVER_STEP: f64 = 0.1;

/// Linear time-heal rate (score per second of silence) — a transiently
/// faulty peer is fully rehabilitated after ~500 s without new evidence.
const HEAL_PER_SEC: f64 = 0.002;

/// Own scores below this are worth gossiping (healthy peers are implied).
const SHARE_BELOW: f64 = 0.95;

/// Max reputation rows piggybacked per gossip message.
const MAX_REP_ROWS: usize = 16;

/// Release hysteresis: quarantine lifts only above `threshold * RELEASE_FACTOR`.
const RELEASE_FACTOR: f64 = 1.5;

/// Floor for the dispatch weight of a non-quarantined peer (keeps alias
/// sampling away from all-zero weight vectors).
const MIN_WEIGHT: f64 = 0.01;

#[derive(Debug, Clone, Copy)]
struct OwnScore {
    score: f64,
    last_update: Time,
}

/// Deterministic per-peer reputation state for one node. See module docs.
#[derive(Debug, Clone, Default)]
pub struct ReputationBook {
    own: BTreeMap<u32, OwnScore>,
    remote: BTreeMap<u32, f64>,
    quarantined: BTreeSet<u32>,
    threshold: f64,
    version: u64,
}

impl ReputationBook {
    pub fn new(quarantine_threshold: f64) -> ReputationBook {
        ReputationBook {
            threshold: quarantine_threshold,
            ..Default::default()
        }
    }

    /// Bumped on every material change — the snapshot-cache staleness key.
    pub fn version(&self) -> u64 {
        self.version
    }

    fn healed(&self, n: u32, now: Time) -> f64 {
        match self.own.get(&n) {
            Some(s) => {
                let dt = (now - s.last_update).max(0.0);
                (s.score + HEAL_PER_SEC * dt).min(1.0)
            }
            None => 1.0,
        }
    }

    /// Effective score: own evidence modulated by bounded remote opinion.
    pub fn effective(&self, n: NodeId, now: Time) -> f64 {
        let own = self.healed(n.0, now);
        let remote = self.remote.get(&n.0).copied().unwrap_or(1.0);
        own * (0.5 + 0.5 * remote)
    }

    /// Dispatch candidate weight: the effective score, floored so healthy
    /// sampling structures never see an all-zero vector.
    pub fn weight(&self, n: NodeId, now: Time) -> f64 {
        self.effective(n, now).max(MIN_WEIGHT)
    }

    pub fn is_quarantined(&self, n: NodeId) -> bool {
        self.quarantined.contains(&n.0)
    }

    pub fn quarantined_count(&self) -> usize {
        self.quarantined.len()
    }

    fn update_quarantine(&mut self, n: u32, now: Time) -> Transition {
        let eff = self.effective(NodeId(n), now);
        if self.quarantined.contains(&n) {
            if eff > self.threshold * RELEASE_FACTOR {
                self.quarantined.remove(&n);
                self.version += 1;
                return Transition::Released;
            }
        } else if eff < self.threshold {
            self.quarantined.insert(n);
            self.version += 1;
            return Transition::Quarantined;
        }
        Transition::None
    }

    /// Fold first-hand evidence about `peer` into its own-evidence score.
    pub fn record(
        &mut self,
        peer: NodeId,
        ev: RepEvent,
        now: Time,
    ) -> Transition {
        let healed = self.healed(peer.0, now);
        let score = match penalty(ev) {
            Some(mult) => healed * mult,
            None => healed + RECOVER_STEP * (1.0 - healed),
        };
        self.own
            .insert(peer.0, OwnScore { score, last_update: now });
        self.version += 1;
        self.update_quarantine(peer.0, now)
    }

    /// Own-evidence rows worth gossiping: `(node, milli-score)` pairs for
    /// peers this node actively distrusts, in ascending node order,
    /// bounded at [`MAX_REP_ROWS`]. Healthy peers are never shipped — the
    /// absence of a row means "no complaints".
    pub fn rep_rows(&self, now: Time) -> Vec<(u32, u32)> {
        let mut out = Vec::new();
        for &n in self.own.keys() {
            let healed = self.healed(n, now);
            if healed < SHARE_BELOW {
                out.push((n, (healed.clamp(0.0, 1.0) * 1000.0) as u32));
                if out.len() >= MAX_REP_ROWS {
                    break;
                }
            }
        }
        out
    }

    /// Merge gossiped reputation rows from a peer as remote opinion.
    /// Malformed rows (milli-score out of range, self-referential) are
    /// dropped. Influence is bounded by construction — see
    /// [`effective`](Self::effective) — so slander alone can never push an
    /// honest peer below the default quarantine threshold. Returns any
    /// quarantine transitions caused (own evidence already present can be
    /// tipped over the edge by corroborating hearsay).
    pub fn merge_remote(
        &mut self,
        me: NodeId,
        rows: &[(u32, u32)],
        now: Time,
    ) -> Vec<(NodeId, Transition)> {
        let mut transitions = Vec::new();
        for &(n, milli) in rows {
            if n == me.0 || milli > 1000 {
                continue;
            }
            let opinion = milli as f64 / 1000.0;
            let old = self.remote.get(&n).copied().unwrap_or(1.0);
            let merged = 0.5 * old + 0.5 * opinion;
            if (merged - old).abs() > 1e-9 {
                self.remote.insert(n, merged);
                self.version += 1;
                let t = self.update_quarantine(n, now);
                if t != Transition::None {
                    transitions.push((NodeId(n), t));
                }
            }
        }
        transitions
    }
}

/// Per-node defense state installed by `World::new` when
/// `defenses.enabled` — the signing key, the network key store, and the
/// reputation book. The default is fully inert (no key material, every
/// gate closed), which is what every node gets in a defenseless world.
#[derive(Debug, Clone, Default)]
pub struct DefenseState {
    cfg: DefenseConfig,
    key: Option<NodeKey>,
    keys: Option<KeyStore>,
    pub rep: ReputationBook,
}

impl DefenseState {
    pub fn new(
        cfg: DefenseConfig,
        key: NodeKey,
        keys: KeyStore,
    ) -> DefenseState {
        cfg.validate();
        DefenseState {
            rep: ReputationBook::new(cfg.quarantine_threshold),
            cfg,
            key: Some(key),
            keys: Some(keys),
        }
    }

    pub fn config(&self) -> DefenseConfig {
        self.cfg
    }

    /// Receipts are attached and verified only when the master switch and
    /// the receipts knob are both on and key material is installed.
    pub fn receipts_on(&self) -> bool {
        self.cfg.enabled && self.cfg.receipts && self.key.is_some()
    }

    /// Reputation tracking/gossip/gating active?
    pub fn reputation_on(&self) -> bool {
        self.cfg.enabled && self.cfg.reputation
    }

    /// Hearsay clamp factor for gossiped RTT rows; infinite (no clamp)
    /// when defenses are off.
    pub fn hearsay_cap(&self) -> f64 {
        if self.cfg.enabled {
            self.cfg.hearsay_cap
        } else {
            f64::INFINITY
        }
    }

    /// This node's signing key (present iff defenses were installed).
    pub fn signing_key(&self) -> Option<&NodeKey> {
        self.key.as_ref()
    }

    /// The network key store for verification.
    pub fn key_store(&self) -> Option<&KeyStore> {
        self.keys.as_ref()
    }

    /// Reputation book when active (None keeps snapshot cache keys at 0).
    pub fn rep_if_on(&self) -> Option<&ReputationBook> {
        if self.reputation_on() {
            Some(&self.rep)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn book() -> ReputationBook {
        ReputationBook::new(0.25)
    }

    #[test]
    fn scores_start_perfect_and_penalties_compound() {
        let mut b = book();
        let p = NodeId(4);
        assert_eq!(b.effective(p, 0.0), 1.0);
        assert_eq!(b.weight(p, 0.0), 1.0);
        b.record(p, RepEvent::Timeout, 0.0);
        let one = b.effective(p, 0.0);
        assert!((one - 0.7).abs() < 1e-12);
        b.record(p, RepEvent::Timeout, 0.0);
        assert!((b.effective(p, 0.0) - 0.49).abs() < 1e-12);
    }

    #[test]
    fn receipt_failures_quarantine_quickly() {
        let mut b = book();
        let p = NodeId(2);
        assert_eq!(b.record(p, RepEvent::ReceiptFail, 0.0), Transition::None);
        // Second strike: 0.4 * 0.4 = 0.16 < 0.25 -> quarantined.
        assert_eq!(
            b.record(p, RepEvent::ReceiptFail, 0.0),
            Transition::Quarantined
        );
        assert!(b.is_quarantined(p));
        assert_eq!(b.quarantined_count(), 1);
        // Repeat offenses while quarantined don't re-announce.
        assert_eq!(b.record(p, RepEvent::ReceiptFail, 0.0), Transition::None);
    }

    #[test]
    fn time_heal_releases_quarantine_with_hysteresis() {
        let mut b = book();
        let p = NodeId(7);
        b.record(p, RepEvent::ReceiptFail, 0.0);
        b.record(p, RepEvent::ReceiptFail, 0.0);
        assert!(b.is_quarantined(p));
        // Healing at 0.002/s from 0.16: release needs eff > 0.375, i.e.
        // ~108 s of silence. A success event after that heals + releases.
        assert_eq!(
            b.record(p, RepEvent::Success, 200.0),
            Transition::Released
        );
        assert!(!b.is_quarantined(p));
        // Effective score keeps rising toward 1.0 afterwards.
        let e = b.effective(p, 200.0);
        assert!(e > 0.375 && e < 1.0, "e={e}");
        assert_eq!(b.effective(p, 2000.0), 1.0, "fully healed");
    }

    #[test]
    fn successes_recover_bounded() {
        let mut b = book();
        let p = NodeId(1);
        b.record(p, RepEvent::DuelLoss, 0.0);
        let low = b.effective(p, 0.0);
        b.record(p, RepEvent::DuelWin, 0.0);
        let up = b.effective(p, 0.0);
        assert!(up > low && up < 1.0);
    }

    #[test]
    fn rep_rows_ship_only_distrusted_peers() {
        let mut b = book();
        b.record(NodeId(3), RepEvent::Timeout, 0.0);
        b.record(NodeId(9), RepEvent::Success, 0.0); // stays ~1.0
        let rows = b.rep_rows(0.0);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].0, 3);
        assert_eq!(rows[0].1, 700);
        // After full heal, nothing ships.
        assert!(b.rep_rows(1000.0).is_empty());
    }

    #[test]
    fn slander_alone_cannot_quarantine() {
        let mut b = book();
        let me = NodeId(0);
        // A colluder claims node 5 is worthless, repeatedly.
        for _ in 0..50 {
            let t = b.merge_remote(me, &[(5, 0)], 0.0);
            assert!(t.is_empty(), "hearsay alone must never quarantine");
        }
        // Bounded influence: effective >= 0.5 with perfect own evidence.
        let e = b.effective(NodeId(5), 0.0);
        assert!((e - 0.5).abs() < 1e-9, "e={e}");
        assert!(!b.is_quarantined(NodeId(5)));
    }

    #[test]
    fn hearsay_corroborates_own_evidence() {
        let mut b = book();
        let me = NodeId(0);
        let p = NodeId(5);
        // One own timeout (0.7) is far above the threshold...
        b.record(p, RepEvent::Timeout, 0.0);
        assert!(!b.is_quarantined(p));
        // ...but strong corroborating hearsay tips it: 0.7 * (0.5 + 0.5 r).
        // After enough zero-opinion merges r -> 0, eff -> 0.35... still
        // above 0.25; add one more own timeout -> 0.49 * 0.5 = 0.245 < 0.25.
        for _ in 0..20 {
            b.merge_remote(me, &[(5, 0)], 0.0);
        }
        assert!(!b.is_quarantined(p));
        let t = b.record(p, RepEvent::Timeout, 0.0);
        assert_eq!(t, Transition::Quarantined);
    }

    #[test]
    fn merge_rejects_malformed_and_self_rows() {
        let mut b = book();
        let me = NodeId(0);
        b.merge_remote(me, &[(0, 100), (4, 5000)], 0.0);
        assert_eq!(b.effective(NodeId(0), 0.0), 1.0, "self row dropped");
        assert_eq!(b.effective(NodeId(4), 0.0), 1.0, "out-of-range dropped");
        assert_eq!(b.version(), 0);
    }

    #[test]
    fn version_bumps_on_material_changes_only() {
        let mut b = book();
        assert_eq!(b.version(), 0);
        b.record(NodeId(1), RepEvent::Timeout, 0.0);
        let v = b.version();
        assert!(v > 0);
        // A merge that doesn't move the stored opinion doesn't bump.
        b.merge_remote(NodeId(0), &[(2, 1000)], 0.0);
        assert_eq!(b.version(), v);
    }

    #[test]
    fn defense_state_default_is_inert() {
        let d = DefenseState::default();
        assert!(!d.receipts_on());
        assert!(!d.reputation_on());
        assert_eq!(d.hearsay_cap(), f64::INFINITY);
        assert!(d.signing_key().is_none());
        assert!(d.rep_if_on().is_none());
    }

    #[test]
    fn defense_state_enabled_arms_all_gates() {
        let cfg = DefenseConfig { enabled: true, ..Default::default() };
        let keys = KeyStore::for_network(1, 4);
        let d = DefenseState::new(cfg, NodeKey::derive(1, NodeId(0)), keys);
        assert!(d.receipts_on());
        assert!(d.reputation_on());
        assert_eq!(d.hearsay_cap(), 3.0);
        assert!(d.signing_key().is_some());
        assert!(d.key_store().is_some());
        assert!(d.rep_if_on().is_some());
    }

    #[test]
    fn config_check_rejects_bad_knobs() {
        assert!(DefenseConfig::default().check().is_ok());
        let bad_thresh = DefenseConfig {
            quarantine_threshold: 1.0,
            ..Default::default()
        };
        assert!(bad_thresh.check().is_err());
        let nan_thresh = DefenseConfig {
            quarantine_threshold: f64::NAN,
            ..Default::default()
        };
        assert!(nan_thresh.check().is_err());
        let bad_cap = DefenseConfig { hearsay_cap: 0.5, ..Default::default() };
        assert!(bad_cap.check().is_err());
    }

    #[test]
    #[should_panic(expected = "hearsay_cap")]
    fn validate_panics_on_bad_cap() {
        DefenseConfig { hearsay_cap: f64::NAN, ..Default::default() }
            .validate();
    }
}
