//! The PJRT execution engine: compile-once, execute-many.
//!
//! Follows /opt/xla-example/load_hlo: HLO **text** -> `HloModuleProto` ->
//! `XlaComputation` -> `PjRtClient::compile`. Parameters upload once as
//! device buffers; per step only the small token/length arrays and the
//! assembled KV batch cross the host-device boundary.

use std::collections::BTreeMap;
use std::path::Path;

use super::{Batcher, Manifest, RuntimeError};

/// One sequence's host-side KV cache (f32, layout [L, H, S, D]).
#[derive(Debug, Clone)]
pub struct SeqKv {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// Valid entries (current sequence length).
    pub len: usize,
}

impl SeqKv {
    pub fn empty(manifest: &Manifest) -> SeqKv {
        let n = manifest.kv_seq_elems();
        SeqKv { k: vec![0.0; n], v: vec![0.0; n], len: 0 }
    }
}

pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    param_bufs: Vec<xla::PjRtBuffer>,
    decode_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    /// batch -> (padded seq len, executable)
    prefill_exes: BTreeMap<usize, (usize, xla::PjRtLoadedExecutable)>,
    pub batcher: Batcher,
    /// Executions performed (perf accounting).
    pub steps_executed: std::cell::Cell<u64>,
}

impl Engine {
    /// Load manifest + params + compile every artifact in `dir`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine, RuntimeError> {
        let dir = dir.as_ref();
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;

        // Parameters: one flat f32 blob, split per the manifest spec.
        let blob = std::fs::read(dir.join("params.bin"))?;
        if blob.len() != manifest.num_params * 4 {
            return Err(RuntimeError::Manifest(format!(
                "params.bin is {} bytes, expected {}",
                blob.len(),
                manifest.num_params * 4
            )));
        }
        // Decode LE f32s (copy: Vec<u8> gives no alignment guarantee).
        let floats: Vec<f32> = blob
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        // NOTE: upload via buffer_from_host_buffer — the crate's
        // buffer_from_host_literal miscomputes buffer sizes after the first
        // call on this xla_extension build (see EXPERIMENTS.md §Notes).
        let mut param_bufs = Vec::with_capacity(manifest.param_spec.len());
        let mut offset = 0usize;
        for (name, shape) in &manifest.param_spec {
            let n: usize = shape.iter().product();
            let buf = client
                .buffer_from_host_buffer(&floats[offset..offset + n], shape, None)
                .map_err(|e| {
                    RuntimeError::Manifest(format!("param {name}: {e}"))
                })?;
            param_bufs.push(buf);
            offset += n;
        }

        let mut decode_exes = BTreeMap::new();
        let mut prefill_exes = BTreeMap::new();
        for art in &manifest.artifacts {
            let proto =
                xla::HloModuleProto::from_text_file(dir.join(&art.path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            match art.kind.as_str() {
                "decode" => {
                    decode_exes.insert(art.batch, exe);
                }
                "prefill" => {
                    prefill_exes.insert(
                        art.batch,
                        (art.seq.unwrap_or(manifest.max_seq), exe),
                    );
                }
                other => {
                    return Err(RuntimeError::Manifest(format!(
                        "unknown artifact kind '{other}'"
                    )))
                }
            }
        }
        if decode_exes.is_empty() {
            return Err(RuntimeError::NoExecutable("decode".into(), 1));
        }
        let batcher = Batcher::new(decode_exes.keys().copied().collect());
        Ok(Engine {
            client,
            manifest,
            param_bufs,
            decode_exes,
            prefill_exes,
            batcher,
            steps_executed: std::cell::Cell::new(0),
        })
    }

    fn upload_f32(
        &self,
        data: &[f32],
        dims: &[usize],
    ) -> Result<xla::PjRtBuffer, RuntimeError> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    fn upload_i32(
        &self,
        data: &[i32],
        dims: &[usize],
    ) -> Result<xla::PjRtBuffer, RuntimeError> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Prefill a wave of prompts. Returns per-sequence (last-token logits,
    /// fresh KV). Prompts longer than the compiled window are truncated to
    /// its tail; empty prompts get a single zero token.
    pub fn prefill(
        &self,
        prompts: &[Vec<u32>],
    ) -> Result<Vec<(Vec<f32>, SeqKv)>, RuntimeError> {
        let n = prompts.len();
        if n == 0 {
            return Ok(vec![]);
        }
        // Smallest compiled prefill batch that fits.
        let (&batch, &(seq, ref exe)) = self
            .prefill_exes
            .iter()
            .find(|(b, _)| **b >= n)
            .or_else(|| self.prefill_exes.iter().next_back())
            .ok_or_else(|| RuntimeError::NoExecutable("prefill".into(), n))?;
        if batch < n {
            // Split into waves recursively.
            let mut out = Vec::with_capacity(n);
            for chunk in prompts.chunks(batch) {
                out.extend(self.prefill(&chunk.to_vec())?);
            }
            return Ok(out);
        }

        let mut tokens = vec![0i32; batch * seq];
        let mut lens = vec![1i32; batch]; // padded rows: len 1, ignored
        for (b, p) in prompts.iter().enumerate() {
            let tail = if p.len() > seq { &p[p.len() - seq..] } else { p };
            for (s, t) in tail.iter().enumerate() {
                tokens[b * seq + s] = *t as i32;
            }
            lens[b] = tail.len().max(1) as i32;
        }

        let mut args: Vec<&xla::PjRtBuffer> = self.param_bufs.iter().collect();
        let tok_buf = self.upload_i32(&tokens, &[batch, seq])?;
        let len_buf = self.upload_i32(&lens, &[batch])?;
        args.push(&tok_buf);
        args.push(&len_buf);

        let result = exe.execute_b(&args)?;
        self.steps_executed.set(self.steps_executed.get() + 1);
        let tuple = result[0][0].to_literal_sync()?;
        let (logits_l, k_l, v_l) = tuple.to_tuple3()?;
        let logits: Vec<f32> = logits_l.to_vec()?;
        let k: Vec<f32> = k_l.to_vec()?;
        let v: Vec<f32> = v_l.to_vec()?;

        let m = &self.manifest;
        let vocab = m.vocab;
        let per_layer = m.kv_layer_elems();
        let mut out = Vec::with_capacity(n);
        for (b, p) in prompts.iter().enumerate() {
            let mut kv = SeqKv::empty(m);
            // Batch KV layout [L, B, H, S, D] -> per-seq [L, H, S, D].
            for l in 0..m.n_layers {
                let src = (l * batch + b) * per_layer;
                let dst = l * per_layer;
                kv.k[dst..dst + per_layer]
                    .copy_from_slice(&k[src..src + per_layer]);
                kv.v[dst..dst + per_layer]
                    .copy_from_slice(&v[src..src + per_layer]);
            }
            kv.len = lens[b] as usize;
            let _ = p;
            out.push((logits[b * vocab..(b + 1) * vocab].to_vec(), kv));
        }
        Ok(out)
    }

    /// One decode step for a wave of sequences (continuous batch). `seqs[i]`
    /// consumes `tokens[i]` and its KV advances by one. Returns per-sequence
    /// next-token logits.
    pub fn decode_step(
        &self,
        seqs: &mut [&mut SeqKv],
        tokens: &[u32],
    ) -> Result<Vec<Vec<f32>>, RuntimeError> {
        let n = seqs.len();
        assert_eq!(n, tokens.len());
        if n == 0 {
            return Ok(vec![]);
        }
        let batch = self.batcher.pick(n);
        let Some(exe) = self.decode_exes.get(&batch) else {
            return Err(RuntimeError::NoExecutable("decode".into(), n));
        };
        if batch < n {
            // Shouldn't happen (pick clamps to max; waves split upstream).
            return Err(RuntimeError::NoExecutable("decode".into(), n));
        }

        let m = &self.manifest;
        let per_layer = m.kv_layer_elems();
        let kv_elems = m.n_layers * batch * per_layer;
        let mut k_batch = vec![0f32; kv_elems];
        let mut v_batch = vec![0f32; kv_elems];
        for (b, s) in seqs.iter().enumerate() {
            for l in 0..m.n_layers {
                let dst = (l * batch + b) * per_layer;
                let src = l * per_layer;
                k_batch[dst..dst + per_layer]
                    .copy_from_slice(&s.k[src..src + per_layer]);
                v_batch[dst..dst + per_layer]
                    .copy_from_slice(&s.v[src..src + per_layer]);
            }
        }
        let mut tok = vec![0i32; batch];
        let mut lens = vec![0i32; batch];
        for (b, s) in seqs.iter().enumerate() {
            tok[b] = tokens[b] as i32;
            lens[b] = s.len.min(m.max_seq - 1) as i32;
        }

        let dims = [m.n_layers, batch, m.n_heads, m.max_seq, m.d_head];
        let mut args: Vec<&xla::PjRtBuffer> = self.param_bufs.iter().collect();
        let k_buf = self.upload_f32(&k_batch, &dims)?;
        let v_buf = self.upload_f32(&v_batch, &dims)?;
        let tok_buf = self.upload_i32(&tok, &[batch])?;
        let len_buf = self.upload_i32(&lens, &[batch])?;
        args.push(&k_buf);
        args.push(&v_buf);
        args.push(&tok_buf);
        args.push(&len_buf);

        let result = exe.execute_b(&args)?;
        self.steps_executed.set(self.steps_executed.get() + 1);
        let tuple = result[0][0].to_literal_sync()?;
        let (logits_l, k_l, v_l) = tuple.to_tuple3()?;
        let logits: Vec<f32> = logits_l.to_vec()?;
        let k: Vec<f32> = k_l.to_vec()?;
        let v: Vec<f32> = v_l.to_vec()?;

        let vocab = m.vocab;
        let mut out = Vec::with_capacity(n);
        for (b, s) in seqs.iter_mut().enumerate() {
            for l in 0..m.n_layers {
                let src = (l * batch + b) * per_layer;
                let dst = l * per_layer;
                s.k[dst..dst + per_layer]
                    .copy_from_slice(&k[src..src + per_layer]);
                s.v[dst..dst + per_layer]
                    .copy_from_slice(&v[src..src + per_layer]);
            }
            s.len = (s.len + 1).min(m.max_seq);
            out.push(logits[b * vocab..(b + 1) * vocab].to_vec());
        }
        Ok(out)
    }

    /// Greedy generation helper: prefill a prompt then decode `max_new`
    /// tokens. Returns the generated token ids.
    pub fn generate(
        &self,
        prompt: &[u32],
        max_new: usize,
    ) -> Result<Vec<u32>, RuntimeError> {
        let mut result = self.prefill(&[prompt.to_vec()])?;
        let (logits, mut kv) = result.remove(0);
        let mut out = Vec::with_capacity(max_new);
        let mut next = argmax(&logits);
        out.push(next);
        for _ in 1..max_new {
            if kv.len >= self.manifest.max_seq - 1 {
                break;
            }
            let logits =
                self.decode_step(&mut [&mut kv], &[next])?.remove(0);
            next = argmax(&logits);
            out.push(next);
        }
        Ok(out)
    }
}

/// Index of the max logit.
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, v) in logits.iter().enumerate() {
        if *v > best_v {
            best_v = *v;
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts");
        if dir.join("manifest.json").exists() {
            Some(dir)
        } else {
            eprintln!("skipping: run `make artifacts` first");
            None
        }
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    fn engine_loads_and_generates() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::load(&dir).unwrap();
        assert!(engine.manifest.vocab > 0);
        let toks = engine.generate(&[1, 2, 3, 4], 8).unwrap();
        assert_eq!(toks.len(), 8);
        for t in &toks {
            assert!((*t as usize) < engine.manifest.vocab);
        }
        // Deterministic (greedy + fixed params).
        let toks2 = engine.generate(&[1, 2, 3, 4], 8).unwrap();
        assert_eq!(toks, toks2);
    }

    #[test]
    fn decode_chain_matches_prefill() {
        // prefill(p + [t]) last-logits == prefill(p) then decode_step(t).
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::load(&dir).unwrap();
        let prompt = vec![5u32, 9, 17, 33];
        let extended: Vec<u32> = prompt
            .iter()
            .copied()
            .chain(std::iter::once(44u32))
            .collect();

        let mut r1 = engine.prefill(&[prompt.clone()]).unwrap();
        let (_, mut kv) = r1.remove(0);
        let step_logits =
            engine.decode_step(&mut [&mut kv], &[44]).unwrap().remove(0);

        let mut r2 = engine.prefill(&[extended]).unwrap();
        let (full_logits, kv2) = r2.remove(0);
        assert_eq!(kv.len, kv2.len);
        let max_diff = step_logits
            .iter()
            .zip(&full_logits)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_diff < 1e-3, "decode vs prefill diverge: {max_diff}");
    }

    #[test]
    fn batched_decode_matches_solo() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::load(&dir).unwrap();
        let prompts = vec![vec![1u32, 2, 3], vec![10u32, 20, 30, 40, 50]];
        let mut waves = engine.prefill(&prompts).unwrap();
        let (_, mut kv_a) = waves.remove(0);
        let (_, mut kv_b) = waves.remove(0);
        let mut kv_a2 = kv_a.clone();
        let mut kv_b2 = kv_b.clone();

        // Packed step.
        let packed = engine
            .decode_step(&mut [&mut kv_a, &mut kv_b], &[7, 8])
            .unwrap();
        // Solo steps.
        let solo_a = engine.decode_step(&mut [&mut kv_a2], &[7]).unwrap();
        let solo_b = engine.decode_step(&mut [&mut kv_b2], &[8]).unwrap();

        for (p, s) in [(&packed[0], &solo_a[0]), (&packed[1], &solo_b[0])] {
            let max_diff = p
                .iter()
                .zip(s.iter())
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(max_diff < 1e-3, "packed vs solo diverge: {max_diff}");
        }
    }
}
