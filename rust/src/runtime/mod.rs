//! PJRT runtime: load `artifacts/*.hlo.txt` (AOT-lowered by
//! `python/compile/aot.py`) and serve the transformer from Rust — Python is
//! never on the request path.
//!
//! * [`Manifest`] — parses `artifacts/manifest.json` (model config, param
//!   spec, executable table).
//! * [`Engine`] — PJRT CPU client; compiles each HLO module once, uploads
//!   the parameters once as device buffers, then serves `prefill` /
//!   `decode_step` calls. KV caches live host-side per sequence
//!   ([`SeqKv`]) and are assembled into fixed-batch device inputs per step —
//!   this is what lets the continuous batcher pack unrelated requests at
//!   different decode positions into one compiled executable.
//! * [`Batcher`] — picks the smallest compiled batch size that fits a wave
//!   of pending sequences (the fixed-shape analogue of vLLM's batching).

pub mod engine;

pub use engine::{Engine, SeqKv};

/// Parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub num_params: usize,
    pub seed: u64,
    /// (name, shape) in params.bin order.
    pub param_spec: Vec<(String, Vec<usize>)>,
    pub artifacts: Vec<ArtifactInfo>,
}

#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub kind: String,
    pub batch: usize,
    /// Padded prompt length (prefill artifacts only).
    pub seq: Option<usize>,
    pub path: String,
}

#[derive(Debug, thiserror::Error)]
pub enum RuntimeError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("manifest: {0}")]
    Manifest(String),
    #[error("xla: {0}")]
    Xla(String),
    #[error("no compiled executable for kind={0} batch>={1}")]
    NoExecutable(String, usize),
}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest, RuntimeError> {
        use crate::util::json::Json;
        let j = Json::parse(text)
            .map_err(|e| RuntimeError::Manifest(e.to_string()))?;
        let m = j.get("model");
        let field = |k: &str| {
            m.get(k)
                .as_usize()
                .ok_or_else(|| RuntimeError::Manifest(format!("model.{k}")))
        };
        let param_spec = j
            .get("param_spec")
            .as_arr()
            .ok_or_else(|| RuntimeError::Manifest("param_spec".into()))?
            .iter()
            .map(|p| {
                let name = p.get("name").as_str().unwrap_or("").to_string();
                let shape = p
                    .get("shape")
                    .as_arr()
                    .map(|a| {
                        a.iter().filter_map(|d| d.as_usize()).collect()
                    })
                    .unwrap_or_default();
                (name, shape)
            })
            .collect();
        let artifacts = j
            .get("artifacts")
            .as_arr()
            .ok_or_else(|| RuntimeError::Manifest("artifacts".into()))?
            .iter()
            .map(|a| ArtifactInfo {
                kind: a.get("kind").as_str().unwrap_or("").to_string(),
                batch: a.get("batch").as_usize().unwrap_or(1),
                seq: a.get("seq").as_usize(),
                path: a.get("path").as_str().unwrap_or("").to_string(),
            })
            .collect();
        Ok(Manifest {
            vocab: field("vocab")?,
            d_model: field("d_model")?,
            n_heads: field("n_heads")?,
            d_head: field("d_head")?,
            n_layers: field("n_layers")?,
            d_ff: field("d_ff")?,
            max_seq: field("max_seq")?,
            num_params: field("num_params")?,
            seed: m.get("seed").as_u64().unwrap_or(0),
            param_spec,
            artifacts,
        })
    }

    pub fn load(dir: &std::path::Path) -> Result<Manifest, RuntimeError> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        Manifest::parse(&text)
    }

    /// Elements in one sequence's KV cache per layer: H * S * D.
    pub fn kv_layer_elems(&self) -> usize {
        self.n_heads * self.max_seq * self.d_head
    }

    /// Elements in one sequence's full KV half (k or v): L * H * S * D.
    pub fn kv_seq_elems(&self) -> usize {
        self.n_layers * self.kv_layer_elems()
    }
}

/// Picks a compiled batch size for a wave of pending sequences.
#[derive(Debug, Clone)]
pub struct Batcher {
    /// Compiled batch sizes, ascending (e.g. [1, 2, 4, 8]).
    sizes: Vec<usize>,
}

impl Batcher {
    pub fn new(mut sizes: Vec<usize>) -> Batcher {
        sizes.sort_unstable();
        sizes.dedup();
        assert!(!sizes.is_empty(), "need at least one compiled batch size");
        Batcher { sizes }
    }

    pub fn max_batch(&self) -> usize {
        *self.sizes.last().unwrap()
    }

    /// Smallest compiled size that fits `n` sequences, or the max size if
    /// `n` exceeds it (the caller splits into waves).
    pub fn pick(&self, n: usize) -> usize {
        for s in &self.sizes {
            if *s >= n {
                return *s;
            }
        }
        self.max_batch()
    }

    /// Split `n` pending sequences into waves of compiled sizes, greedily
    /// largest-first (minimizes number of executions).
    pub fn waves(&self, mut n: usize) -> Vec<usize> {
        let mut out = Vec::new();
        while n > 0 {
            if n >= self.max_batch() {
                out.push(self.max_batch());
                n -= self.max_batch();
            } else {
                out.push(self.pick(n));
                n = 0;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "model": {"config": "test", "vocab": 64, "d_model": 32, "n_heads": 2,
                "d_head": 16, "n_layers": 2, "d_ff": 64, "max_seq": 32,
                "num_params": 22016, "seed": 0},
      "param_spec": [{"name": "embed", "shape": [64, 32]},
                     {"name": "pos_embed", "shape": [32, 32]}],
      "artifacts": [
        {"kind": "decode", "batch": 1, "seq": null, "path": "decode_b1.hlo.txt",
         "num_param_args": 29, "extra_args": [], "results": []},
        {"kind": "prefill", "batch": 4, "seq": 32, "path": "prefill_b4_s32.hlo.txt",
         "num_param_args": 29, "extra_args": [], "results": []}
      ]
    }"#;

    #[test]
    fn parse_manifest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.vocab, 64);
        assert_eq!(m.n_layers, 2);
        assert_eq!(m.param_spec.len(), 2);
        assert_eq!(m.param_spec[0].1, vec![64, 32]);
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.artifacts[1].seq, Some(32));
        assert_eq!(m.kv_layer_elems(), 2 * 32 * 16);
        assert_eq!(m.kv_seq_elems(), 2 * 2 * 32 * 16);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    #[test]
    fn batcher_pick_and_waves() {
        let b = Batcher::new(vec![8, 1, 4, 2, 2]);
        assert_eq!(b.pick(1), 1);
        assert_eq!(b.pick(3), 4);
        assert_eq!(b.pick(8), 8);
        assert_eq!(b.pick(20), 8);
        assert_eq!(b.waves(0), Vec::<usize>::new());
        assert_eq!(b.waves(3), vec![4]);
        assert_eq!(b.waves(19), vec![8, 8, 4]);
        assert_eq!(b.max_batch(), 8);
    }
}
