//! Baseline scheduling strategies compared against WWW.Serve in Figure 4 /
//! Table 2.
//!
//! * **Single** — each node serves only its own users; no cooperation. The
//!   paper's "single-node deployment".
//! * **Centralized** — an omniscient global dispatcher places every request
//!   on the node with the least normalized outstanding work (it sees exact
//!   queue depths everywhere, pays no probe round-trips and needs no
//!   credits — the upper-bound baseline the paper's decentralized scheduler
//!   approaches).
//!
//! Both run on the same `SimBackend`s and workload traces as the
//! decentralized [`crate::sim::World`], so the comparison isolates the
//! scheduling strategy.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::backend::{Backend, Profile, SimBackend};
use crate::metrics::Recorder;
use crate::types::{ExecKind, NodeId, Request, RequestRecord, Time};
use crate::util::rng::Rng;
use crate::workload::Generator;

/// Strategy selector used by benches and the CLI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    Single,
    Centralized,
    Decentralized,
}

impl Strategy {
    pub fn name(self) -> &'static str {
        match self {
            Strategy::Single => "single",
            Strategy::Centralized => "centralized",
            Strategy::Decentralized => "decentralized",
        }
    }
}

/// One node of the baseline harness.
pub struct BaselineNode {
    pub backend: SimBackend,
}

#[derive(Debug)]
enum Ev {
    Arrival { origin: usize, req: Request },
    Wake { node: usize },
}

struct Queued {
    t: Time,
    seq: u64,
    ev: Ev,
}

impl PartialEq for Queued {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for Queued {}
impl PartialOrd for Queued {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Queued {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t
            .partial_cmp(&other.t)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(self.seq.cmp(&other.seq))
    }
}

/// Shared baseline engine. `centralized == false` pins every request to its
/// origin node (Single); `true` lets the global dispatcher place it.
pub struct BaselineSim {
    nodes: Vec<BaselineNode>,
    queue: BinaryHeap<Reverse<Queued>>,
    seq: u64,
    rng: Rng,
    centralized: bool,
    net_latency: (f64, f64),
    pub recorder: Recorder,
}

impl BaselineSim {
    pub fn new(
        profiles: Vec<Profile>,
        generators: Vec<Option<Generator>>,
        centralized: bool,
        seed: u64,
    ) -> BaselineSim {
        assert_eq!(profiles.len(), generators.len());
        // detlint:allow(D003) reason="baseline-sim root RNG lineage, seeded from the caller's seed"
        let mut rng = Rng::new(seed);
        let mut sim = BaselineSim {
            nodes: profiles
                .into_iter()
                .map(|p| BaselineNode { backend: SimBackend::new(p) })
                .collect(),
            queue: BinaryHeap::new(),
            seq: 0,
            rng: rng.fork(0xBA5E),
            centralized,
            net_latency: (0.02, 0.08),
            recorder: Recorder::new(),
        };
        for (i, g) in generators.into_iter().enumerate() {
            if let Some(mut g) = g {
                let mut grng = rng.fork(1000 + i as u64);
                for req in g.trace(&mut grng) {
                    sim.push(req.submitted_at, Ev::Arrival { origin: i, req });
                }
            }
        }
        sim
    }

    fn push(&mut self, t: Time, ev: Ev) {
        self.seq += 1;
        self.queue.push(Reverse(Queued { t, seq: self.seq, ev }));
    }

    fn latency(&mut self) -> Time {
        let (lo, hi) = self.net_latency;
        self.rng.range_f64(lo, hi)
    }

    /// Least normalized outstanding work. The score estimates seconds of
    /// queued generation per unit of aggregate decode capacity.
    fn pick_node(&self, origin: usize) -> usize {
        if !self.centralized {
            return origin;
        }
        let mut best = origin;
        let mut best_score = f64::INFINITY;
        for (i, n) in self.nodes.iter().enumerate() {
            let outstanding =
                (n.backend.running_len() + n.backend.queue_len()) as f64;
            let capacity = n.backend.profile().max_agg_decode_tok_s;
            let score = (outstanding + 1.0) / capacity;
            if score < best_score {
                best_score = score;
                best = i;
            }
        }
        best
    }

    pub fn run_until(&mut self, horizon: Time) {
        // Baselines run the queue dry (all arrivals are < horizon; we let
        // in-flight work finish so latency stats cover every request).
        while let Some(Reverse(q)) = self.queue.pop() {
            let now = q.t;
            match q.ev {
                Ev::Arrival { origin, req } => {
                    let target = self.pick_node(origin);
                    let (submit_time, remote) = if target != origin {
                        (now + self.latency(), true)
                    } else {
                        (now, false)
                    };
                    let rec_meta = (origin, target, remote);
                    self.nodes[target].backend.submit(
                        req.clone(),
                        if remote { ExecKind::Delegated } else { ExecKind::Local },
                        submit_time,
                    );
                    let _ = rec_meta;
                    if let Some(t) = self.nodes[target].backend.next_event() {
                        self.push(t, Ev::Wake { node: target });
                    }
                }
                Ev::Wake { node } => {
                    let completions = self.nodes[node].backend.advance(now);
                    for c in completions {
                        let remote = c.kind == ExecKind::Delegated;
                        let back = if remote { self.latency() } else { 0.0 };
                        self.recorder.record(RequestRecord {
                            id: c.request.id,
                            origin: c.request.id.origin,
                            executor: NodeId(node as u32),
                            kind: c.kind,
                            prompt_tokens: c.request.prompt_tokens,
                            output_tokens: c.request.output_tokens,
                            submitted_at: c.request.submitted_at,
                            completed_at: c.finished_at + back,
                            slo_deadline: c.request.slo_deadline,
                            synthetic: c.request.synthetic,
                            session: c.request.session,
                            ttft_deadline: c.request.ttft_deadline,
                            first_token_at: c.first_token_at,
                        });
                    }
                    if let Some(t) = self.nodes[node].backend.next_event() {
                        self.push(t, Ev::Wake { node });
                    }
                }
            }
            let _ = horizon;
        }
    }

    pub fn node_backend(&self, i: usize) -> &SimBackend {
        &self.nodes[i].backend
    }
}

/// Run the Single strategy over a workload.
pub fn run_single(
    profiles: Vec<Profile>,
    generators: Vec<Option<Generator>>,
    horizon: Time,
    seed: u64,
) -> Recorder {
    let mut sim = BaselineSim::new(profiles, generators, false, seed);
    sim.run_until(horizon);
    sim.recorder
}

/// Run the Centralized strategy over a workload.
pub fn run_centralized(
    profiles: Vec<Profile>,
    generators: Vec<Option<Generator>>,
    horizon: Time,
    seed: u64,
) -> Recorder {
    let mut sim = BaselineSim::new(profiles, generators, true, seed);
    sim.run_until(horizon);
    sim.recorder
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Phase;

    fn gens(n: usize, ia: f64, horizon: f64) -> Vec<Option<Generator>> {
        (0..n)
            .map(|i| {
                Some(Generator::new(
                    NodeId(i as u32),
                    vec![Phase::new(0.0, horizon, ia)],
                ))
            })
            .collect()
    }

    #[test]
    fn single_serves_everything_locally() {
        let profiles = vec![Profile::test(40.0, 8); 3];
        let rec = run_single(profiles, gens(3, 5.0, 100.0), 100.0, 1);
        assert!(rec.len() > 20);
        for r in rec.all() {
            assert_eq!(r.origin, r.executor);
            assert_eq!(r.kind, ExecKind::Local);
        }
    }

    #[test]
    fn centralized_offloads_from_hot_node() {
        // Node 0 gets a flood; nodes 1-2 idle. Centralized must spread.
        let profiles = vec![Profile::test(40.0, 4); 3];
        let mut generators = gens(1, 0.5, 100.0);
        generators.push(None);
        generators.push(None);
        let rec = run_centralized(profiles, generators, 100.0, 2);
        let served = rec.served_by();
        assert!(served.len() >= 2, "no spreading: {served:?}");
    }

    #[test]
    fn centralized_beats_single_under_skew() {
        // Heavy skew on node 0; total capacity is plentiful.
        let profiles = vec![Profile::test(40.0, 4); 4];
        let mut generators = gens(1, 1.0, 200.0);
        for _ in 1..4 {
            generators.push(None);
        }
        let single =
            run_single(profiles.clone(), generators.clone(), 200.0, 3);
        let central = run_centralized(profiles, generators, 200.0, 3);
        assert!(
            central.mean_latency() < single.mean_latency(),
            "centralized {} vs single {}",
            central.mean_latency(),
            single.mean_latency()
        );
        assert!(central.slo_attainment() >= single.slo_attainment());
    }

    #[test]
    fn deterministic() {
        let profiles = vec![Profile::test(40.0, 4); 3];
        let a = run_centralized(profiles.clone(), gens(3, 2.0, 100.0), 100.0, 9)
            .mean_latency();
        let b = run_centralized(profiles, gens(3, 2.0, 100.0), 100.0, 9)
            .mean_latency();
        assert_eq!(a, b);
    }
}
