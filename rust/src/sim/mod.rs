//! Deterministic discrete-event simulation of a WWW.Serve network.
//!
//! [`World`] owns the nodes, the event queue, a latency-modelled message
//! fabric, the metrics recorder and the credit samplers. Virtual time means
//! the paper's 750-second experiments run in milliseconds, bit-identically
//! reproducible from the seed — every integration test and every
//! figure-regenerating bench drives this harness.

pub mod queue;

use std::sync::{Arc, Mutex};

use crate::backend::{Profile, SimBackend};
use crate::capacity::{
    CapacityAction, CapacityGroupSpec, CapacityPolicyKind, GroupController,
    MemberState,
};
use crate::coordinator::{Action, Event, LedgerManager, Node};
use crate::crypto::{KeyStore, NodeKey};
use crate::duel::DuelStats;
use crate::gossip::GossipConfig;
use crate::latency::LatencyConfig;
use crate::ledger::{Block, CreditOp, OpReason, SharedLedger};
use crate::metrics::{Recorder, TimeSeries};
use crate::obs::{
    export, FlightRecorder, MetricId, MetricsRegistry, ObservabilityConfig,
    SpanEvent, SpanKind,
};
use crate::policy::{
    ByzantineKind, NodePolicy, ParticipationKind, SystemPolicy,
};
use crate::reputation::{DefenseConfig, DefenseState};
use crate::streaming::StreamingConfig;
use crate::topology::Topology;
use crate::types::{NodeId, Time};
use crate::util::rng::Rng;
use crate::workload::Generator;

use self::queue::EventQueue;

/// Which consistency machinery backs the credit system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LedgerMode {
    /// The paper's Appendix-C shared ledger.
    Shared,
    /// Full per-node Credit Block Chain replicas with propose/vote/commit.
    Blockchain,
}

/// World-level configuration.
#[derive(Debug, Clone)]
pub struct WorldConfig {
    pub seed: u64,
    pub system: SystemPolicy,
    pub gossip: GossipConfig,
    pub ledger: LedgerMode,
    /// Uniform one-way message latency range in seconds — the flat network
    /// model, used when `topology` is `None` (wrapped into a single-region
    /// [`Topology`] that replays bit-identically).
    pub net_latency: (f64, f64),
    /// Geo-distributed WAN structure: regions, link matrix, node placement
    /// and scheduled partitions. `None` = flat single-region network.
    pub topology: Option<Topology>,
    /// Live latency estimation knobs (EWMA alpha, staleness decay, prior
    /// weight, summary share rate). `enabled = false` freezes dispatch on
    /// the static expected-latency matrix — the pre-estimator baseline.
    pub latency_estimation: LatencyConfig,
    /// Node pump period (gossip rounds, timeout scans).
    pub tick_interval: f64,
    /// Period for sampling per-node credit totals (Figure 6 curves);
    /// 0 disables sampling.
    pub credit_sample_interval: f64,
    /// Scheduled availability changes `(node index, time, join)` — e.g.
    /// expanded from declarative fleet `churn` blocks. Installed by
    /// [`World::new`], so a churn-declaring config cannot silently lose
    /// its schedule; `schedule_join`/`schedule_leave` remain for ad-hoc
    /// test scripting.
    pub churn: Vec<(usize, f64, bool)>,
    /// Elastic-capacity groups (the declarative `capacity` blocks on
    /// `topology.fleet` groups — see the [`crate::capacity`] module).
    /// A `Static`-policy group installs no controller and leaves the
    /// trace of a capacity-free world untouched bit for bit
    /// (`rust/tests/replay_equivalence.rs`).
    pub capacity: Vec<CapacityGroupSpec>,
    /// Causal request tracing + metrics-registry sampling (see
    /// [`crate::obs`]). Disabled by default, which replays
    /// pre-observability event traces byte for byte; enabling it is
    /// purely observational (no queue events, no RNG draws), so replay
    /// fingerprints still match.
    pub observability: ObservabilityConfig,
    /// Byzantine-robustness defenses (signed work receipts, per-peer
    /// reputation with quarantine, gossip hearsay capping — see
    /// [`crate::reputation`]). Disabled by default: no receipts on the
    /// wire, no reputation rows in gossip, no extra RNG draws, so
    /// pre-defense configs replay byte for byte.
    pub defenses: DefenseConfig,
    /// Blockchain-mode chain sync: answer anchored `ChainRequest`s with
    /// just the missing block suffix (`ChainDelta`) instead of a full
    /// `ChainSnapshot`. On by default; `false` reproduces the seed's
    /// full-replica shipping — the baseline the fleet-scale bench compares
    /// `chain_sync_bytes_sent` against. Ignored in shared-ledger mode.
    pub chain_delta_sync: bool,
    /// Streaming-session semantics: disaggregated prefill/decode
    /// admission, KV-affine dispatch, and the executor-side churn NACK
    /// (see [`crate::streaming`]). Disabled by default: dispatch stays
    /// session-blind, admission unified, and the RNG draw sequence
    /// untouched, so pre-streaming configs replay byte for byte.
    pub streaming: StreamingConfig,
}

impl Default for WorldConfig {
    fn default() -> Self {
        WorldConfig {
            seed: 0,
            system: SystemPolicy::default(),
            gossip: GossipConfig::default(),
            ledger: LedgerMode::Shared,
            net_latency: (0.02, 0.08),
            topology: None,
            latency_estimation: LatencyConfig::default(),
            tick_interval: 1.0,
            credit_sample_interval: 5.0,
            churn: Vec::new(),
            capacity: Vec::new(),
            observability: ObservabilityConfig::default(),
            defenses: DefenseConfig::default(),
            chain_delta_sync: true,
            streaming: StreamingConfig::default(),
        }
    }
}

impl WorldConfig {
    /// Panics with a descriptive message on invalid configuration — the
    /// seed silently clamped an inverted latency range; misconfigured
    /// experiments should fail loudly at construction instead.
    pub fn validate(&self) {
        let (lo, hi) = self.net_latency;
        assert!(
            lo.is_finite() && hi.is_finite() && lo >= 0.0,
            "WorldConfig.net_latency bounds must be finite and non-negative, \
             got ({lo}, {hi})"
        );
        assert!(lo <= hi, "WorldConfig.net_latency: lo {lo} > hi {hi}");
        assert!(
            self.tick_interval > 0.0 && self.tick_interval.is_finite(),
            "WorldConfig.tick_interval must be > 0, got {}",
            self.tick_interval
        );
        assert!(
            self.credit_sample_interval >= 0.0,
            "WorldConfig.credit_sample_interval must be >= 0, got {}",
            self.credit_sample_interval
        );
        for &(_, at, _) in &self.churn {
            assert!(
                at.is_finite() && at >= 0.0,
                "WorldConfig.churn times must be finite and >= 0, got {at}"
            );
        }
        self.latency_estimation.validate();
        for spec in &self.capacity {
            spec.cfg.validate();
        }
        self.observability.validate();
        self.defenses.validate();
        self.streaming.validate();
    }
}

/// Everything needed to stand up one node.
#[derive(Debug, Clone)]
pub struct NodeSetup {
    pub profile: Profile,
    pub policy: NodePolicy,
    /// User-request arrival schedule (None = no local users).
    pub generator: Option<Generator>,
    /// Start offline (joins later via `schedule_join`).
    pub start_offline: bool,
    /// Which participation behaviour the node runs (the trait object is
    /// built at `World::new`; `Default` reproduces the scalar-knob
    /// behaviour bit for bit).
    pub participation: ParticipationKind,
    /// Reporting label (fleet group name) for per-policy-group summaries;
    /// None for ungrouped nodes.
    pub group: Option<String>,
    /// Byzantine attacker personality (see [`crate::policy::byzantine`]);
    /// when set it overrides `participation` at world build. None = honest.
    pub byzantine: Option<ByzantineKind>,
}

impl NodeSetup {
    pub fn new(profile: Profile, policy: NodePolicy) -> Self {
        NodeSetup {
            profile,
            policy,
            generator: None,
            start_offline: false,
            participation: ParticipationKind::Default,
            group: None,
            byzantine: None,
        }
    }

    pub fn with_generator(mut self, g: Generator) -> Self {
        self.generator = Some(g);
        self
    }

    pub fn offline(mut self) -> Self {
        self.start_offline = true;
        self
    }

    pub fn with_participation(mut self, kind: ParticipationKind) -> Self {
        self.participation = kind;
        self
    }

    pub fn with_group(mut self, label: impl Into<String>) -> Self {
        self.group = Some(label.into());
        self
    }

    pub fn with_byzantine(mut self, kind: ByzantineKind) -> Self {
        self.byzantine = Some(kind);
        self
    }
}

/// Internal queue entry.
#[derive(Debug)]
enum WorldEvent {
    Node(usize, Event),
    Tick(usize),
    SampleCredits,
    /// Apply scheduled topology event `idx` (degrade/partition/heal).
    Link(usize),
    /// Evaluate capacity-group controller `gi` (elastic scaling round).
    /// Only enqueued for worlds with an active capacity group, so
    /// capacity-free (and static-capacity) configs replay the exact seed
    /// event sequence.
    Capacity(usize),
}

/// Virtual-time cadence of the metrics-registry sampling rounds inside
/// `run_until` (piggybacked on event processing — no queue entries of
/// its own, so the replay stream is untouched).
const OBS_SAMPLE_INTERVAL: Time = 5.0;

/// Pre-interned [`MetricsRegistry`] handles for the counters `run_until`
/// mirrors each sampling round — labels resolve once at construction,
/// the loop updates by id.
struct ObsMetricIds {
    events_processed: MetricId,
    messages_sent: MetricId,
    bytes_sent: MetricId,
    gossip_messages_sent: MetricId,
    gossip_bytes_sent: MetricId,
    chain_sync_messages_sent: MetricId,
    chain_sync_bytes_sent: MetricId,
    kv_transfer_count: MetricId,
    kv_transfer_bytes: MetricId,
    messages_dropped: MetricId,
    scale_events: MetricId,
    capacity_credits_charged: MetricId,
    requests_completed: MetricId,
    /// Probe + Delegate sends per (origin, destination) region pair,
    /// row-major — the labeled mirror of `World::dispatch_matrix`.
    dispatch_sends: Vec<MetricId>,
    /// Per-origin-region completion-latency histograms.
    region_latency: Vec<MetricId>,
    /// Per-node availability gauges (1 online, 0 offline).
    node_online: Vec<MetricId>,
}

/// The simulated network.
pub struct World {
    pub cfg: WorldConfig,
    nodes: Vec<Node>,
    /// Central event scheduler: a calendar queue popping in exact
    /// `(time, push-seq)` order — the seed heap's order, proven by the
    /// same-tape oracle in `rust/tests/event_queue_oracle.rs`.
    queue: EventQueue<WorldEvent>,
    now: Time,
    rng: Rng,
    next_wake: Vec<Time>,
    /// WAN structure every message routes through (single-region when
    /// `cfg.topology` is None — replays the flat model bit-for-bit).
    topology: Topology,
    /// Only present in Shared ledger mode.
    shared: Option<Arc<Mutex<SharedLedger>>>,
    pub recorder: Recorder,
    pub duel_stats: DuelStats,
    /// Per-node total credits over time (Figure 6 left panels).
    pub credit_series: Vec<TimeSeries>,
    /// Per-node running-request counts over time (Figure 8a/8b).
    pub running_series: Vec<TimeSeries>,
    pub messages_sent: u64,
    pub bytes_sent: u64,
    /// Gossip-protocol share of the totals (full digests, deltas and
    /// replies) — the fleet-scale bench tracks these against the
    /// full-digest baseline.
    pub gossip_messages_sent: u64,
    pub gossip_bytes_sent: u64,
    /// Chain-state shipping share of the totals (blockchain-mode
    /// anti-entropy responses: `ChainSnapshot` / `ChainDelta`) — the
    /// fleet-scale bench compares delta shipping against the
    /// full-snapshot baseline on these. The constant-rate 48-byte
    /// `ChainRequest` probes are deliberately excluded: they cost the
    /// same under either protocol and would drown the shipping ratio;
    /// they still count toward `messages_sent`/`bytes_sent`. Zero in
    /// shared-ledger mode.
    pub chain_sync_messages_sent: u64,
    pub chain_sync_bytes_sent: u64,
    /// Session-KV migrations: a `KvTransfer` ships resident context to a
    /// non-home executor, paying for the KV bytes over the fabric's
    /// bandwidth model. The streaming bench compares affinity-aware vs
    /// affinity-blind dispatch on these (zero with streaming disabled).
    pub kv_transfer_count: u64,
    pub kv_transfer_bytes: u64,
    /// Messages lost to partitioned links.
    pub messages_dropped: u64,
    /// Queue entries processed by `run_until` (events/sec denominator for
    /// the perf-tracking benches).
    pub events_processed: u64,
    /// Dispatch-pressure counters: Probe + Delegate sends per
    /// (origin region, destination region), row-major — the reroute bench
    /// windows over these to prove a partitioned region is shed.
    dispatch_matrix: Vec<u64>,
    /// Active elastic-capacity controllers (built from `cfg.capacity`;
    /// empty when every group is an inert static declaration).
    capacity: Vec<GroupController>,
    /// Availability accounting for node-hours: closed online seconds per
    /// node, plus the open interval's start (None while offline).
    online_secs: Vec<f64>,
    online_since: Vec<Option<Time>>,
    /// Capacity scale actions applied so far (slot rescales + standby
    /// activations + replica retirements).
    pub scale_events: u64,
    /// Micro-credits actually burned as capacity holding cost
    /// (`OpReason::CapacityHold`) across all groups — charges clamp to
    /// each replica's liquid balance, and only the clamped amount counts.
    pub capacity_credits_charged: u64,
    /// World-level flight recorder: `scale` spans for capacity actions
    /// the sim core applies on the controllers' behalf (per-node request
    /// spans live on each node's own recorder).
    obs: FlightRecorder,
    /// Unified labeled metrics registry mirroring the public counter
    /// fields above, sampled every [`OBS_SAMPLE_INTERVAL`] virtual
    /// seconds inside `run_until`. Empty while observability is off.
    registry: MetricsRegistry,
    obs_ids: Option<ObsMetricIds>,
    /// Virtual time of the last registry sampling round.
    obs_last_sample: Time,
    /// Recorder cursor: completions already folded into the per-region
    /// latency histograms.
    obs_seen_records: usize,
}

impl World {
    pub fn new(cfg: WorldConfig, setups: Vec<NodeSetup>) -> World {
        let n = setups.len();
        cfg.validate();
        let topology = cfg
            .topology
            .clone()
            .unwrap_or_else(|| Topology::single_region(cfg.net_latency));
        topology.validate(n);
        let geo = topology.num_regions() > 1;
        let latency_est = topology.expected_latency_matrix();
        // detlint:allow(D003) reason="the world's root RNG lineage, seeded from config"
        let mut rng = Rng::new(cfg.seed);
        let shared = match cfg.ledger {
            LedgerMode::Shared => Some(Arc::new(Mutex::new(SharedLedger::new()))),
            LedgerMode::Blockchain => None,
        };
        // Blockchain mode: one genesis block, known to every replica.
        let keys = KeyStore::for_network(cfg.seed, n as u32);
        let genesis_block = if cfg.ledger == LedgerMode::Blockchain {
            let mut ops = Vec::new();
            for (i, s) in setups.iter().enumerate() {
                let id = NodeId(i as u32);
                ops.push(CreditOp::Mint {
                    to: id,
                    amount: cfg.system.genesis_credits,
                    reason: OpReason::Genesis,
                });
                let stake = s.policy.stake.min(cfg.system.genesis_credits);
                if stake > 0 {
                    ops.push(CreditOp::Stake { node: id, amount: stake });
                }
            }
            Some(Block::create(
                crate::crypto::Hash256::ZERO,
                0.0,
                ops,
                &NodeKey::derive(cfg.seed, NodeId(0)),
            ))
        } else {
            None
        };

        let mut nodes = Vec::with_capacity(n);
        for (i, setup) in setups.iter().enumerate() {
            let id = NodeId(i as u32);
            let ledger = match cfg.ledger {
                LedgerMode::Shared => {
                    LedgerManager::shared(shared.as_ref().unwrap().clone())
                }
                LedgerMode::Blockchain => {
                    let quorum = n / 2 + 1;
                    let mut m = LedgerManager::chain(
                        NodeKey::derive(cfg.seed, id),
                        keys.clone(),
                        quorum,
                    );
                    if let LedgerManager::Chain(r) = &mut m {
                        r.delta_sync = cfg.chain_delta_sync;
                        if let Some(g) = &genesis_block {
                            r.chain
                                .commit_block(g.clone(), &keys)
                                .expect("genesis block valid");
                        }
                    }
                    m
                }
            };
            let mut backend = SimBackend::new(setup.profile)
                .with_priority(setup.policy.prioritize_own);
            // Streaming mode: split the backend's unified admission into a
            // compute-bound prefill pool and the KV-gated decode pool
            // (0 = "prefill pool as wide as max_batch").
            if cfg.streaming.enabled {
                let slots = if cfg.streaming.prefill_slots == 0 {
                    setup.profile.max_batch
                } else {
                    cfg.streaming.prefill_slots
                };
                backend = backend.with_split_pools(slots);
            }
            let participation = setup.participation;
            let mut node = Node::new(
                id,
                setup.policy,
                cfg.system,
                Box::new(backend),
                ledger,
                cfg.gossip,
                cfg.seed.wrapping_mul(31).wrapping_add(i as u64),
                0.0,
            );
            // Participation behaviour (construction-time, no RNG impact;
            // `Default` installs the bit-identical legacy behaviour). A
            // declared Byzantine personality overrides it outright.
            match setup.byzantine {
                Some(kind) => node.set_participation(kind.build()),
                None => node.set_participation(participation.build()),
            }
            // Streaming knobs (KV-affine dispatch, churn NACK). The
            // default (disabled) block is inert — dispatch spends exactly
            // the classic RNG draws.
            node.set_streaming(cfg.streaming);
            // Byzantine defenses: key material + reputation book. Off (the
            // default) installs nothing, keeping the wire format and event
            // stream bit-identical to the defenseless network.
            if cfg.defenses.enabled {
                node.set_defenses(DefenseState::new(
                    cfg.defenses,
                    NodeKey::derive(cfg.seed, id),
                    keys.clone(),
                ));
            }
            // Geo placement: tag the node with its region and hand it the
            // pristine expected-latency matrix as the live estimator's
            // cold-start prior so `latency_penalty` can bite.
            if geo {
                node.set_locality(
                    topology.region_of(i) as u32,
                    latency_est.clone(),
                    cfg.latency_estimation,
                );
            }
            // Arm the per-node flight recorder. Construction-time and
            // purely observational, so the replay stream is untouched.
            if cfg.observability.enabled {
                node.set_observability(cfg.observability);
            }
            // Bootstrap membership: everyone knows everyone's address (and
            // home region); the initially-offline are seeded as offline
            // (they gossip alive when they join — Fig. 5a).
            for (j, other) in setups.iter().enumerate() {
                if i == j {
                    continue;
                }
                let jid = NodeId(j as u32);
                let jregion = topology.region_of(j) as u32;
                if other.start_offline {
                    node.view.merge(&[(jid, 0, false, 0, jregion)], 0.0);
                } else {
                    node.view.add_seed(jid, 0, jregion, 0.0);
                }
            }
            // Every node was just seeded with the same membership: that is
            // common knowledge, so deltas must not re-ship it on first
            // contact (see `PeerView::seal_bootstrap`).
            node.view.seal_bootstrap();
            if setup.start_offline {
                node.online = false;
            }
            nodes.push(node);
        }

        let num_regions = topology.num_regions();
        // Elastic capacity: validate every declared group, but install a
        // controller only for reactive policies — a static declaration is
        // inert by contract (`CapacityConfig::check` rejects live knobs on
        // it), and must leave the event sequence untouched.
        let mut capacity_ctrls = Vec::new();
        for spec in &cfg.capacity {
            for &m in spec.members.iter().chain(spec.standby.iter()) {
                assert!(
                    m < n,
                    "capacity group '{}' references node {m} out of range \
                     ({n} nodes)",
                    spec.label
                );
            }
            assert!(
                (spec.region as usize) < num_regions,
                "capacity group '{}' region {} out of range ({num_regions} \
                 regions)",
                spec.label,
                spec.region
            );
            if spec.cfg.policy == CapacityPolicyKind::Reactive {
                capacity_ctrls.push(GroupController::new(spec.clone()));
            }
        }
        let online_since: Vec<Option<Time>> = nodes
            .iter()
            .map(|node| if node.online { Some(0.0) } else { None })
            .collect();
        // Metrics registry: intern every mirrored counter once, with
        // per-region / per-node labels, so the run loop updates by id.
        let (registry, obs_ids) = if cfg.observability.enabled {
            let mut reg = MetricsRegistry::new();
            let ids = ObsMetricIds {
                events_processed: reg.counter("events_processed", &[]),
                messages_sent: reg.counter("messages_sent", &[]),
                bytes_sent: reg.counter("bytes_sent", &[]),
                gossip_messages_sent: reg
                    .counter("gossip_messages_sent", &[]),
                gossip_bytes_sent: reg.counter("gossip_bytes_sent", &[]),
                chain_sync_messages_sent: reg
                    .counter("chain_sync_messages_sent", &[]),
                chain_sync_bytes_sent: reg
                    .counter("chain_sync_bytes_sent", &[]),
                kv_transfer_count: reg.counter("kv_transfer_count", &[]),
                kv_transfer_bytes: reg.counter("kv_transfer_bytes", &[]),
                messages_dropped: reg.counter("messages_dropped", &[]),
                scale_events: reg.counter("scale_events", &[]),
                capacity_credits_charged: reg
                    .counter("capacity_credits_charged", &[]),
                requests_completed: reg.counter("requests_completed", &[]),
                dispatch_sends: (0..num_regions)
                    .flat_map(|a| (0..num_regions).map(move |b| (a, b)))
                    .map(|(a, b)| {
                        reg.counter(
                            "dispatch_sends",
                            &[
                                ("from", topology.region_name(a)),
                                ("to", topology.region_name(b)),
                            ],
                        )
                    })
                    .collect(),
                region_latency: (0..num_regions)
                    .map(|r| {
                        reg.histogram(
                            "request_latency_s",
                            &[("region", topology.region_name(r))],
                        )
                    })
                    .collect(),
                node_online: (0..n)
                    .map(|i| {
                        // detlint:allow(D006) reason="construction-time metric labels: the export boundary, not a hot path"
                        let node = format!("n{i}");
                        reg.gauge("node_online", &[("node", &node)])
                    })
                    .collect(),
            };
            (reg, Some(ids))
        } else {
            (MetricsRegistry::new(), None)
        };
        let mut world = World {
            cfg: cfg.clone(),
            nodes,
            queue: EventQueue::new(),
            now: 0.0,
            rng: rng.fork(0xF00D),
            next_wake: vec![f64::INFINITY; n],
            topology,
            shared,
            recorder: Recorder::new(),
            duel_stats: DuelStats::default(),
            credit_series: vec![TimeSeries::new(); n],
            running_series: vec![TimeSeries::new(); n],
            messages_sent: 0,
            bytes_sent: 0,
            gossip_messages_sent: 0,
            gossip_bytes_sent: 0,
            chain_sync_messages_sent: 0,
            chain_sync_bytes_sent: 0,
            kv_transfer_count: 0,
            kv_transfer_bytes: 0,
            messages_dropped: 0,
            events_processed: 0,
            dispatch_matrix: vec![0; num_regions * num_regions],
            capacity: capacity_ctrls,
            online_secs: vec![0.0; n],
            online_since,
            scale_events: 0,
            capacity_credits_charged: 0,
            obs: FlightRecorder::new(cfg.observability),
            registry,
            obs_ids,
            obs_last_sample: 0.0,
            obs_seen_records: 0,
        };

        // Arrival traces.
        for (i, setup) in setups.into_iter().enumerate() {
            if let Some(mut g) = setup.generator {
                let mut grng = world.rng.fork(1000 + i as u64);
                // Falls back to the plain trace, draw for draw, when the
                // generator has no session profile.
                for req in g.session_trace(&mut grng) {
                    let t = req.submitted_at;
                    world.push(t, WorldEvent::Node(i, Event::UserRequest(req)));
                }
            }
        }
        // Ticks.
        for i in 0..n {
            world.push(cfg.tick_interval, WorldEvent::Tick(i));
        }
        // Credit samples.
        if cfg.credit_sample_interval > 0.0 {
            world.push(cfg.credit_sample_interval, WorldEvent::SampleCredits);
        }
        // Scheduled WAN scenario (degrade/partition/heal). Pushed last so a
        // topology-free world enqueues exactly the seed's event sequence.
        let link_times: Vec<(usize, Time)> = world
            .topology
            .events()
            .iter()
            .enumerate()
            .map(|(idx, ev)| (idx, ev.at))
            .collect();
        for (idx, at) in link_times {
            world.push(at, WorldEvent::Link(idx));
        }
        // Declarative churn schedule (fleet `churn` blocks): installed
        // here so a parsed schedule cannot be silently dropped by a caller
        // that forgets an extra step.
        for &(node, at, join) in &cfg.churn {
            assert!(
                node < n,
                "WorldConfig.churn node {node} out of range ({n} nodes)"
            );
            let ev = if join { Event::Join } else { Event::Leave };
            world.push(at, WorldEvent::Node(node, ev));
        }
        // Capacity-controller cadence — pushed last, and only for active
        // groups, so capacity-free configs enqueue the seed's exact event
        // sequence.
        let evals: Vec<(usize, f64)> = world
            .capacity
            .iter()
            .enumerate()
            .map(|(gi, c)| (gi, c.spec.cfg.eval_every))
            .collect();
        for (gi, every) in evals {
            world.push(every, WorldEvent::Capacity(gi));
        }
        world
    }

    // ---- scheduling ---------------------------------------------------------

    fn push(&mut self, t: Time, ev: WorldEvent) {
        self.queue.push(t, ev);
    }

    /// Bring a node online at `t` (Figure 5a).
    pub fn schedule_join(&mut self, node: usize, t: Time) {
        self.push(t, WorldEvent::Node(node, Event::Join));
    }

    /// Take a node offline at `t` (Figure 5b).
    pub fn schedule_leave(&mut self, node: usize, t: Time) {
        self.push(t, WorldEvent::Node(node, Event::Leave));
    }

    /// Inject an extra user request (tests).
    pub fn schedule_request(&mut self, node: usize, req: crate::types::Request) {
        let t = req.submitted_at;
        self.push(t, WorldEvent::Node(node, Event::UserRequest(req)));
    }

    /// One-way delay for a message from node `src` to node `dst`, routed
    /// through the topology's link matrix; `None` when the connecting link
    /// is partitioned (the message is lost). Single-region topologies
    /// reproduce the seed's flat `sample_latency` draw exactly.
    fn sample_delay(&mut self, src: usize, dst: usize, bytes: usize) -> Option<Time> {
        self.topology.sample_delay(src, dst, bytes, &mut self.rng)
    }

    // ---- the loop -----------------------------------------------------------

    /// Run until the queue drains or `horizon` passes. Returns final time.
    pub fn run_until(&mut self, horizon: Time) -> Time {
        while let Some(t) = self.queue.peek_time() {
            if t > horizon {
                break;
            }
            let (t, ev) = self.queue.pop().expect("peeked");
            self.events_processed += 1;
            self.now = t.max(self.now);
            match ev {
                WorldEvent::Node(i, ev) => {
                    if matches!(ev, Event::BackendWake) {
                        self.next_wake[i] = f64::INFINITY;
                    }
                    let was_online = self.nodes[i].online;
                    let actions = self.nodes[i].handle(ev, self.now);
                    if self.nodes[i].online != was_online {
                        self.availability_changed(i);
                    }
                    self.apply(i, actions);
                }
                WorldEvent::Tick(i) => {
                    let actions = self.nodes[i].handle(Event::Tick, self.now);
                    self.apply(i, actions);
                    let next = self.now + self.cfg.tick_interval;
                    self.push(next, WorldEvent::Tick(i));
                }
                WorldEvent::SampleCredits => {
                    self.sample_credits();
                    let next = self.now + self.cfg.credit_sample_interval;
                    self.push(next, WorldEvent::SampleCredits);
                }
                WorldEvent::Link(idx) => {
                    self.topology.apply_event(idx);
                }
                WorldEvent::Capacity(gi) => {
                    self.eval_capacity(gi);
                    let next =
                        self.now + self.capacity[gi].spec.cfg.eval_every;
                    self.push(next, WorldEvent::Capacity(gi));
                }
            }
            // Registry sampling piggybacks on event processing instead of
            // scheduling its own queue entries — enabling observability
            // must not shift the replay stream by a single event.
            if self.obs_ids.is_some()
                && self.now - self.obs_last_sample >= OBS_SAMPLE_INTERVAL
            {
                self.sample_registry();
            }
        }
        self.now = horizon.max(self.now);
        // End-of-run flush so the final counter values always land in the
        // series (idempotent: a repeat sample at an unchanged timestamp
        // is skipped).
        if self.obs_ids.is_some() {
            self.sample_registry();
        }
        self.now
    }

    /// Mirror the public counter fields into the registry and push one
    /// windowed sample per metric. Purely observational — no queue
    /// events, no RNG draws — so replay fingerprints are untouched.
    fn sample_registry(&mut self) {
        let Some(ids) = &self.obs_ids else { return };
        self.registry
            .set(ids.events_processed, self.events_processed as f64);
        self.registry.set(ids.messages_sent, self.messages_sent as f64);
        self.registry.set(ids.bytes_sent, self.bytes_sent as f64);
        self.registry
            .set(ids.gossip_messages_sent, self.gossip_messages_sent as f64);
        self.registry
            .set(ids.gossip_bytes_sent, self.gossip_bytes_sent as f64);
        self.registry.set(
            ids.chain_sync_messages_sent,
            self.chain_sync_messages_sent as f64,
        );
        self.registry
            .set(ids.chain_sync_bytes_sent, self.chain_sync_bytes_sent as f64);
        self.registry
            .set(ids.kv_transfer_count, self.kv_transfer_count as f64);
        self.registry
            .set(ids.kv_transfer_bytes, self.kv_transfer_bytes as f64);
        self.registry
            .set(ids.messages_dropped, self.messages_dropped as f64);
        self.registry.set(ids.scale_events, self.scale_events as f64);
        self.registry.set(
            ids.capacity_credits_charged,
            self.capacity_credits_charged as f64,
        );
        for (i, &id) in ids.dispatch_sends.iter().enumerate() {
            self.registry.set(id, self.dispatch_matrix[i] as f64);
        }
        for (i, &id) in ids.node_online.iter().enumerate() {
            self.registry.set(id, self.nodes[i].online as u8 as f64);
        }
        // Completions recorded since the previous round feed the
        // per-origin-region latency histograms.
        let recs = self.recorder.all();
        let from = self.obs_seen_records.min(recs.len());
        for rec in &recs[from..] {
            if rec.synthetic {
                continue;
            }
            let r = self.topology.region_of(rec.origin.0 as usize);
            self.registry.observe(ids.region_latency[r], rec.latency());
        }
        self.obs_seen_records = recs.len();
        self.registry.set(
            ids.requests_completed,
            self.recorder.user_records().count() as f64,
        );
        self.registry.sample_all(self.now);
        self.obs_last_sample = self.now;
    }

    /// Node `i` just flipped availability: settle the node-hours interval.
    fn availability_changed(&mut self, i: usize) {
        if self.nodes[i].online {
            self.online_since[i] = Some(self.now);
        } else if let Some(since) = self.online_since[i].take() {
            self.online_secs[i] += self.now - since;
        }
    }

    /// One elastic-capacity controller round: gather the group's local
    /// signals (backend pressure, windowed region SLO, live latency to the
    /// nearest remote region), let the group's [`capacity::CapacityPolicy`]
    /// decide, and apply the resulting scale/charge actions.
    ///
    /// [`capacity::CapacityPolicy`]: crate::capacity::CapacityPolicy
    fn eval_capacity(&mut self, gi: usize) {
        let now = self.now;
        let group_nodes = self.capacity[gi].all_nodes();
        let states: Vec<MemberState> = group_nodes
            .iter()
            .map(|&i| {
                let node = &self.nodes[i];
                let b = node.backend();
                // A backend without a split pool reports usize::MAX for
                // prefill_slots; normalize to 0 = "no prefill lever".
                let prefill_slots = match b.prefill_slots() {
                    usize::MAX => 0,
                    s => s,
                };
                MemberState {
                    node: i,
                    online: node.online,
                    utilization: if node.online { b.utilization() } else { 0.0 },
                    queue_len: b.queue_len(),
                    slots: b.slots(),
                    prefill_slots,
                    prefill_util: if node.online && prefill_slots > 0 {
                        b.prefill_running() as f64 / prefill_slots as f64
                    } else {
                        0.0
                    },
                }
            })
            .collect();
        // Windowed SLO pressure of the group's home region: miss fraction
        // of the completions recorded since the previous evaluation.
        let (slo_pressure, seen) = {
            let region = self.capacity[gi].spec.region as usize;
            let recs = self.recorder.all();
            let from = self.capacity[gi].seen_records.min(recs.len());
            let (mut met, mut total) = (0usize, 0usize);
            for rec in &recs[from..] {
                if !rec.synthetic
                    && self.topology.region_of(rec.origin.0 as usize)
                        == region
                {
                    met += rec.slo_met() as usize;
                    total += 1;
                }
            }
            let pressure = if total == 0 {
                0.0
            } else {
                1.0 - met as f64 / total as f64
            };
            (pressure, recs.len())
        };
        self.capacity[gi].seen_records = seen;
        // Live one-way latency to the nearest *other* region, read from
        // the first online member's estimator — the group's own vantage
        // point. Infinity when there is no remote region to lean on.
        let remote_latency = group_nodes
            .iter()
            .filter(|&&i| self.nodes[i].online)
            .find_map(|&i| self.nodes[i].latency_estimator())
            .map(|est| {
                let me = est.my_region();
                (0..est.num_regions() as u32)
                    .filter(|&r| r != me)
                    .map(|r| est.expected_from_me(r, now))
                    .fold(f64::INFINITY, f64::min)
            })
            .unwrap_or(f64::INFINITY);
        let actions = self.capacity[gi].evaluate(
            &states,
            slo_pressure,
            remote_latency,
            now,
        );
        for a in actions {
            // Scale span on the world-level recorder (capacity actions
            // are applied by the sim core, not by any node), plus a
            // per-kind labeled counter in the registry.
            self.obs.node_span(
                SpanKind::Scale,
                NodeId(a.node() as u32),
                None,
                now,
                a.detail(),
            );
            if self.obs.enabled() {
                let id = self
                    .registry
                    .counter("scale_actions", &[("kind", a.kind_name())]);
                self.registry.add(id, 1.0);
            }
            match a {
                CapacityAction::SetSlots { node, slots } => {
                    self.nodes[node].backend_mut().set_slots(slots, now);
                    // A grown cap may have admitted queued work directly
                    // into the backend, bypassing `Node::handle`'s pump —
                    // schedule an immediate wake so completions surface
                    // now, not at the next tick.
                    self.push(now, WorldEvent::Node(node, Event::BackendWake));
                    self.scale_events += 1;
                }
                CapacityAction::SetPrefillSlots { node, slots } => {
                    self.nodes[node]
                        .backend_mut()
                        .set_prefill_slots(slots, now);
                    // Same wake rationale as SetSlots: a grown prefill
                    // pool admits parked work immediately.
                    self.push(now, WorldEvent::Node(node, Event::BackendWake));
                    self.scale_events += 1;
                }
                CapacityAction::Activate { node } => {
                    self.push(now, WorldEvent::Node(node, Event::Join));
                    self.scale_events += 1;
                }
                CapacityAction::Retire { node } => {
                    self.push(now, WorldEvent::Node(node, Event::Leave));
                    self.scale_events += 1;
                }
                CapacityAction::Charge { node, amount } => {
                    // Holding costs burn from the replica's own account.
                    // Blockchain mode would need the node itself to
                    // propose the block; the commitment economics are
                    // modelled on the shared ledger only.
                    if !self.nodes[node].ledger().is_chain() {
                        let id = NodeId(node as u32);
                        // Burns clamp to the liquid balance at apply time;
                        // count only what actually leaves the account so
                        // the counter matches the ledger's `burned`.
                        let burned = amount
                            .min(self.nodes[node].ledger().balance(id));
                        let _ = self.nodes[node].ledger_mut().submit(
                            vec![CreditOp::Burn {
                                from: id,
                                amount,
                                reason: OpReason::CapacityHold,
                            }],
                            id,
                            &[],
                            now,
                        );
                        self.capacity_credits_charged += burned;
                    }
                }
            }
        }
    }

    fn apply(&mut self, from: usize, actions: Vec<Action>) {
        for a in actions {
            match a {
                Action::Send { to, msg } => {
                    self.messages_sent += 1;
                    let bytes = msg.wire_size();
                    self.bytes_sent += bytes as u64;
                    if matches!(
                        msg,
                        crate::coordinator::Message::Gossip { .. }
                            | crate::coordinator::Message::GossipReply { .. }
                            | crate::coordinator::Message::GossipDelta { .. }
                            | crate::coordinator::Message::GossipDeltaReply { .. }
                    ) {
                        self.gossip_messages_sent += 1;
                        self.gossip_bytes_sent += bytes as u64;
                    }
                    if matches!(
                        msg,
                        crate::coordinator::Message::ChainSnapshot { .. }
                            | crate::coordinator::Message::ChainDelta { .. }
                    ) {
                        self.chain_sync_messages_sent += 1;
                        self.chain_sync_bytes_sent += bytes as u64;
                    }
                    if let crate::coordinator::Message::KvTransfer {
                        kv_bytes,
                        ..
                    } = &msg
                    {
                        self.kv_transfer_count += 1;
                        self.kv_transfer_bytes += *kv_bytes;
                    }
                    if matches!(
                        msg,
                        crate::coordinator::Message::Probe { .. }
                            | crate::coordinator::Message::Delegate { .. }
                            | crate::coordinator::Message::KvTransfer { .. }
                    ) {
                        let nr = self.topology.num_regions();
                        let a = self.topology.region_of(from);
                        let b = self.topology.region_of(to.0 as usize);
                        self.dispatch_matrix[a * nr + b] += 1;
                    }
                    match self.sample_delay(from, to.0 as usize, bytes) {
                        Some(lat) => {
                            let ev =
                                Event::Message { from: NodeId(from as u32), msg };
                            self.push(
                                self.now + lat,
                                WorldEvent::Node(to.0 as usize, ev),
                            );
                        }
                        // Partitioned link: the fabric silently eats the
                        // message; timeouts and gossip aging do the rest.
                        None => self.messages_dropped += 1,
                    }
                }
                Action::Done(rec) => self.recorder.record(rec),
                Action::WakeAt(t) => {
                    // Clamp a hair into the future: a wake exactly at `now`
                    // would re-fire forever on float dust.
                    let t = t.max(self.now + 1e-9);
                    if t < self.next_wake[from] - 1e-12 {
                        self.next_wake[from] = t;
                        self.push(t, WorldEvent::Node(from, Event::BackendWake));
                    }
                }
                Action::DuelSettled(o) => self.duel_stats.record(&o),
            }
        }
    }

    fn sample_credits(&mut self) {
        for (i, node) in self.nodes.iter().enumerate() {
            let total = node.credits() as f64 / crate::types::CREDIT as f64;
            self.credit_series[i].push(self.now, total);
            self.running_series[i]
                .push(self.now, node.backend().running_len() as f64);
        }
    }

    // ---- inspection ---------------------------------------------------------

    pub fn now(&self) -> Time {
        self.now
    }

    pub fn node(&self, i: usize) -> &Node {
        &self.nodes[i]
    }

    pub fn node_mut(&mut self, i: usize) -> &mut Node {
        &mut self.nodes[i]
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn shared_ledger(&self) -> Option<Arc<Mutex<SharedLedger>>> {
        self.shared.clone()
    }

    /// The WAN structure this world routes through.
    pub fn topology(&self) -> &Topology {
        &self.topology
    }

    /// Probe + Delegate messages sent so far from region `a` to region `b`
    /// — the dispatch-pressure counter. Snapshot before/after `run_until`
    /// stages to window delegation over time (the reroute scenario does).
    pub fn dispatch_sends(&self, a: usize, b: usize) -> u64 {
        self.dispatch_matrix[a * self.topology.num_regions() + b]
    }

    /// Seconds node `i` has spent online so far, including the currently
    /// open interval — the node-hours accounting the elastic-capacity
    /// bench compares against static peak provisioning.
    pub fn node_seconds_online(&self, i: usize) -> f64 {
        self.online_secs[i]
            + self.online_since[i].map_or(0.0, |since| self.now - since)
    }

    /// The active capacity controllers' group specs (empty when no
    /// reactive/charging capacity group is installed).
    pub fn capacity_groups(&self) -> Vec<&CapacityGroupSpec> {
        self.capacity.iter().map(|c| &c.spec).collect()
    }

    /// Per-region user-request summary keyed by *origin* region:
    /// `(region name, SLO attainment, p99 latency, completed)`. A
    /// single-region world returns one row covering everything.
    ///
    /// Single pass over the recorder: each record is bucketed by its origin
    /// region once, instead of cloning the matching slice of the record log
    /// per region via `Recorder::filtered`.
    pub fn region_summary(&self) -> Vec<(String, f64, f64, usize)> {
        // Resolve interned region ids to names once, here at the
        // boundary — the aggregation itself never touches a string.
        self.region_summary_ids()
            .into_iter()
            .map(|(r, slo, p99, n)| {
                (self.topology.region_name(r).to_string(), slo, p99, n)
            })
            .collect()
    }

    /// [`World::region_summary`] keyed by interned region id instead of
    /// resolved name — the allocation-free form for hot/repeated callers
    /// (per-round bench sampling, capacity evaluation loops).
    pub fn region_summary_ids(&self) -> Vec<(usize, f64, f64, usize)> {
        let nr = self.topology.num_regions();
        let mut met = vec![0usize; nr];
        let mut latencies: Vec<Vec<f64>> = vec![Vec::new(); nr];
        for rec in self.recorder.all().iter().filter(|r| !r.synthetic) {
            let r = self.topology.region_of(rec.origin.0 as usize);
            met[r] += rec.slo_met() as usize;
            latencies[r].push(rec.latency());
        }
        (0..nr)
            .map(|r| {
                let lat = &mut latencies[r];
                let n = lat.len();
                let slo = if n == 0 { 0.0 } else { met[r] as f64 / n as f64 };
                lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
                // Index formula matches `Recorder::latency_percentile`.
                let p99 = if n == 0 {
                    0.0
                } else {
                    lat[((n - 1) as f64 * 0.99).round() as usize]
                };
                (r, slo, p99, n)
            })
            .collect()
    }

    /// Total credits per node at the end of a run.
    pub fn credit_totals(&self) -> Vec<f64> {
        self.nodes
            .iter()
            .map(|n| n.credits() as f64 / crate::types::CREDIT as f64)
            .collect()
    }

    // ---- observability ------------------------------------------------------

    /// The unified metrics registry (empty while observability is off).
    pub fn registry(&self) -> &MetricsRegistry {
        &self.registry
    }

    /// Every recorded span event: each node's flight recorder in node
    /// order, then the world-level ring (capacity `scale` spans).
    fn all_span_events(&self) -> Vec<SpanEvent> {
        let mut out = Vec::new();
        for node in &self.nodes {
            out.extend(node.flight_recorder().events().cloned());
        }
        out.extend(self.obs.events().cloned());
        out
    }

    /// Node-scoped span events (gossip rounds, RTT observations, scale
    /// actions) — everything that is not part of a request's trace.
    pub fn node_span_events(&self) -> Vec<SpanEvent> {
        self.all_span_events()
            .into_iter()
            .filter(|e| e.req.is_none())
            .collect()
    }

    /// Stitch every recorded request-scoped span into per-request trees.
    /// With `slo_misses_only` set, only traces whose request missed its
    /// SLO — or never completed at all — survive into the result.
    pub fn span_trees(&self) -> Vec<export::SpanTree> {
        let trees = export::stitch(self.all_span_events());
        if !self.cfg.observability.slo_misses_only {
            return trees;
        }
        let met: std::collections::BTreeMap<_, _> = self
            .recorder
            .user_records()
            .map(|r| (r.id, r.slo_met()))
            .collect();
        trees
            .into_iter()
            .filter(|t| !met.get(&t.req).copied().unwrap_or(false))
            .collect()
    }

    /// The run's Chrome trace-event JSON document (see [`crate::obs`]).
    pub fn trace_json(&self) -> crate::util::json::Json {
        export::chrome_trace_json(&self.span_trees(), &self.node_span_events())
    }

    /// Write the Chrome trace-event file — load it in `chrome://tracing`
    /// or <https://ui.perfetto.dev>.
    pub fn write_trace(&self, path: &str) -> std::io::Result<()> {
        export::write_chrome_trace(
            path,
            &self.span_trees(),
            &self.node_span_events(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{LinkChange, LinkProfile};
    use crate::workload::Phase;

    fn setup_uniform(n: usize, ia: f64) -> Vec<NodeSetup> {
        (0..n)
            .map(|i| {
                NodeSetup::new(Profile::test(40.0, 16), NodePolicy::default())
                    .with_generator(
                        Generator::new(
                            NodeId(i as u32),
                            vec![Phase::new(0.0, 100.0, ia)],
                        )
                        // Short outputs keep these smoke workloads feasible
                        // on the small test profiles.
                        .with_lengths(crate::workload::LengthDist {
                            output_mean: 1200.0,
                            output_sigma: 0.5,
                            ..Default::default()
                        }),
                    )
            })
            .collect()
    }

    #[test]
    fn smoke_run_completes_requests() {
        let mut w = World::new(WorldConfig::default(), setup_uniform(3, 5.0));
        w.run_until(400.0);
        assert!(w.recorder.len() > 20, "only {} records", w.recorder.len());
        assert!(w.recorder.slo_attainment() > 0.0);
        // All user requests eventually completed (3 nodes * ~20 arrivals).
        let submitted: u64 =
            (0..3).map(|i| w.node(i).stats.user_requests).sum();
        assert_eq!(w.recorder.user_records().count() as u64, submitted);
    }

    #[test]
    fn deterministic_replay() {
        let run = |seed: u64| {
            let cfg = WorldConfig { seed, ..Default::default() };
            let mut w = World::new(cfg, setup_uniform(4, 3.0));
            w.run_until(300.0);
            (
                w.recorder.len(),
                (w.recorder.mean_latency() * 1e9) as u64,
                w.messages_sent,
                w.credit_totals()
                    .iter()
                    .map(|c| (c * 1e6) as u64)
                    .collect::<Vec<_>>(),
            )
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn blockchain_mode_converges_with_shared() {
        let mk = |ledger| {
            let cfg = WorldConfig { ledger, seed: 3, ..Default::default() };
            let mut w = World::new(cfg, setup_uniform(4, 4.0));
            w.run_until(200.0);
            w
        };
        let ws = mk(LedgerMode::Shared);
        let wb = mk(LedgerMode::Blockchain);
        // Same workload completes in both modes.
        assert!(wb.recorder.len() > 10);
        let d = (ws.recorder.len() as i64 - wb.recorder.len() as i64).abs();
        assert!(d < 10, "shared {} vs chain {}", ws.recorder.len(), wb.recorder.len());
        // Chain replicas actually accumulated blocks.
        let chain_len = match wb.node(0).ledger() {
            LedgerManager::Chain(_) => {
                // length probed through balances — every node paid something
                true
            }
            _ => false,
        };
        assert!(chain_len);
    }

    #[test]
    fn gossip_discovers_joined_node() {
        let mut setups = setup_uniform(3, 4.0);
        setups.push(
            NodeSetup::new(Profile::test(40.0, 8), NodePolicy::default())
                .offline(),
        );
        let mut w = World::new(WorldConfig::default(), setups);
        w.schedule_join(3, 50.0);
        w.run_until(200.0);
        // After joining + gossip, the other nodes see node 3 alive.
        for i in 0..3 {
            assert!(
                w.node(i).view.is_alive(NodeId(3), w.now()),
                "node {i} doesn't see node 3"
            );
        }
    }

    #[test]
    fn leave_is_detected() {
        let mut w = World::new(WorldConfig::default(), setup_uniform(4, 4.0));
        w.schedule_leave(2, 50.0);
        w.run_until(200.0);
        for i in [0usize, 1, 3] {
            assert!(
                !w.node(i).view.is_alive(NodeId(2), w.now()),
                "node {i} still sees node 2"
            );
        }
    }

    #[test]
    fn duels_occur_and_settle() {
        let cfg = WorldConfig {
            system: SystemPolicy { duel_rate: 0.5, ..Default::default() },
            ..Default::default()
        };
        // Overload one node so it delegates a lot.
        let mut setups = setup_uniform(4, 30.0);
        setups[0] = NodeSetup::new(Profile::test(40.0, 2), NodePolicy {
            target_utilization: 0.1,
            ..Default::default()
        })
        .with_generator(
            Generator::new(NodeId(0), vec![Phase::new(0.0, 100.0, 3.0)])
                .with_lengths(crate::workload::LengthDist {
                    output_mean: 1200.0,
                    output_sigma: 0.5,
                    ..Default::default()
                }),
        );
        let mut w = World::new(cfg, setups);
        w.run_until(2000.0);
        assert!(
            w.duel_stats.total_duels() > 3,
            "only {} duels settled",
            w.duel_stats.total_duels()
        );
    }

    #[test]
    fn explicit_single_region_topology_matches_flat() {
        // Backward compatibility: wrapping the flat latency range into a
        // one-region topology must replay the identical simulation.
        let fingerprint = |cfg: WorldConfig| {
            let mut w = World::new(cfg, setup_uniform(4, 3.0));
            w.run_until(300.0);
            (
                w.recorder.len(),
                (w.recorder.mean_latency() * 1e9) as u64,
                w.messages_sent,
                w.messages_dropped,
                w.credit_totals()
                    .iter()
                    .map(|c| (c * 1e6) as u64)
                    .collect::<Vec<_>>(),
            )
        };
        let flat = fingerprint(WorldConfig { seed: 11, ..Default::default() });
        let topo = fingerprint(WorldConfig {
            seed: 11,
            topology: Some(Topology::single_region((0.02, 0.08))),
            ..Default::default()
        });
        assert_eq!(flat, topo);
        assert_eq!(flat.3, 0, "no drops without partitions");
    }

    #[test]
    #[should_panic(expected = "net_latency")]
    fn inverted_net_latency_panics() {
        let cfg = WorldConfig { net_latency: (0.08, 0.02), ..Default::default() };
        World::new(cfg, setup_uniform(2, 5.0));
    }

    #[test]
    #[should_panic(expected = "node assignments")]
    fn topology_node_count_mismatch_panics() {
        let topo = Topology::builder()
            .region("a")
            .region("b")
            .nodes("a", 5)
            .build();
        let cfg = WorldConfig { topology: Some(topo), ..Default::default() };
        World::new(cfg, setup_uniform(3, 5.0));
    }

    #[test]
    fn full_partition_drops_messages_and_splits_views() {
        // Two regions, two nodes each; the inter link partitions at t=30
        // and never heals. Cross-region peers must age out of the gossip
        // views while intra-region peers stay alive.
        let topo = Topology::builder()
            .region("west")
            .region("east")
            .default_intra(LinkProfile::new(0.001, 0.004))
            .link("west", "east", LinkProfile::new(0.04, 0.06))
            .nodes("west", 2)
            .nodes("east", 2)
            .event("west", "east", 30.0, LinkChange::Partition)
            .build();
        let cfg = WorldConfig {
            seed: 5,
            topology: Some(topo),
            ..Default::default()
        };
        let mut w = World::new(cfg, setup_uniform(4, 1e12));
        w.run_until(120.0);
        assert!(w.messages_dropped > 0, "partition dropped nothing");
        let now = w.now();
        // Intra-region liveness survives; cross-region is suspected dead.
        assert!(w.node(0).view.is_alive(NodeId(1), now));
        assert!(w.node(2).view.is_alive(NodeId(3), now));
        assert!(!w.node(0).view.is_alive(NodeId(2), now));
        assert!(!w.node(3).view.is_alive(NodeId(0), now));
        // Per-region grouping reflects the split world.
        let by = w.node(0).view.alive_peers_by_region(now);
        assert_eq!(by.get(&0), Some(&vec![NodeId(1)]));
        assert!(by.get(&1).is_none());
    }

    #[test]
    fn region_summary_single_pass_matches_filtered_oracle() {
        // The one-pass aggregation must reproduce exactly what the
        // clone-per-region `Recorder::filtered` computation produced.
        let topo = crate::topology::three_region_wan(2).build();
        let cfg =
            WorldConfig { seed: 9, topology: Some(topo), ..Default::default() };
        let mut w = World::new(cfg, setup_uniform(6, 4.0));
        w.run_until(400.0);
        assert!(w.recorder.len() > 20, "workload barely ran");
        let summary = w.region_summary();
        assert_eq!(summary.len(), 3);
        for (r, row) in summary.iter().enumerate() {
            let oracle = w.recorder.filtered(|rec| {
                w.topology().region_of(rec.origin.0 as usize) == r
            });
            assert_eq!(row.0, w.topology().region_name(r));
            assert!((row.1 - oracle.slo_attainment()).abs() < 1e-12);
            assert!(
                (row.2 - oracle.latency_percentile(0.99).unwrap_or(0.0)).abs()
                    < 1e-12
            );
            assert_eq!(row.3, oracle.user_records().count());
        }
    }

    #[test]
    fn gossip_traffic_counters_track_subset() {
        let mut w = World::new(WorldConfig::default(), setup_uniform(3, 5.0));
        w.run_until(100.0);
        assert!(w.gossip_messages_sent > 0);
        assert!(w.gossip_messages_sent <= w.messages_sent);
        assert!(w.gossip_bytes_sent <= w.bytes_sent);
        assert!(w.events_processed > 0);
    }

    #[test]
    fn dispatch_counters_track_probe_and_delegate_sends() {
        // Single-region world: every Probe/Delegate lands in (0, 0), and
        // the counter moves only when delegation traffic exists.
        let mut setups = setup_uniform(3, 2.0);
        setups[0].policy.target_utilization = 0.0;
        setups[0].policy.offload_freq = 1.0;
        let mut w = World::new(WorldConfig::default(), setups);
        assert_eq!(w.dispatch_sends(0, 0), 0);
        w.run_until(200.0);
        assert!(
            w.dispatch_sends(0, 0) > 0,
            "an always-offloading node sent no probes"
        );
    }

    #[test]
    fn credits_flow_to_executors() {
        // Node 0 is a pure requester; nodes 1-3 serve. Servers should end
        // richer than genesis, node 0 poorer.
        let mut setups = vec![NodeSetup::new(
            Profile::test(1.0, 1),
            NodePolicy::requester_only(),
        )
        .with_generator(Generator::new(
            NodeId(0),
            vec![Phase::new(0.0, 200.0, 2.0)],
        ))];
        for _ in 1..4 {
            setups.push(NodeSetup::new(
                Profile::test(60.0, 16),
                NodePolicy { accept_freq: 1.0, ..Default::default() },
            ));
        }
        let mut w = World::new(WorldConfig::default(), setups);
        w.run_until(800.0);
        let totals = w.credit_totals();
        let genesis =
            SystemPolicy::default().genesis_credits as f64 / crate::types::CREDIT as f64;
        assert!(totals[0] < genesis, "requester didn't pay: {totals:?}");
        assert!(
            totals[1] > genesis || totals[2] > genesis || totals[3] > genesis,
            "no server earned: {totals:?}"
        );
    }

    // ---- elastic capacity ---------------------------------------------------

    use crate::capacity::{
        CapacityConfig, CapacityGroupSpec, CapacityPolicyKind,
    };

    /// Node 0 floods requests over [0, 120); node 1 is the committed
    /// server, nodes 2 and 3 are standby replicas stamped offline.
    fn elastic_setups() -> Vec<NodeSetup> {
        let mut setups = vec![NodeSetup::new(
            Profile::test(40.0, 4),
            NodePolicy::requester_only(),
        )
        .with_generator(
            Generator::new(NodeId(0), vec![Phase::new(0.0, 120.0, 1.0)])
                .with_lengths(crate::workload::LengthDist {
                    output_mean: 300.0,
                    output_sigma: 0.4,
                    ..Default::default()
                }),
        )];
        for i in 1..4u32 {
            let mut s = NodeSetup::new(
                Profile::test(40.0, 4),
                NodePolicy {
                    stake: 20 * crate::types::CREDIT,
                    accept_freq: 1.0,
                    ..Default::default()
                },
            );
            if i > 1 {
                s = s.offline();
            }
            setups.push(s);
        }
        setups
    }

    fn elastic_spec() -> CapacityGroupSpec {
        CapacityGroupSpec {
            label: "flat/elastic".into(),
            region: 0,
            members: vec![1],
            standby: vec![2, 3],
            cfg: CapacityConfig {
                policy: CapacityPolicyKind::Reactive,
                scale_up_util: 0.8,
                scale_down_util: 0.2,
                cooldown: 5.0,
                eval_every: 2.0,
                online_cost_per_hour: 3600.0, // 1 credit / online second
                standby_cost_per_hour: 36.0,
                ..Default::default()
            },
        }
    }

    #[test]
    fn elastic_capacity_rides_a_load_wave() {
        let mut cfg = WorldConfig { seed: 5, ..Default::default() };
        cfg.system.duel_rate = 0.0;
        cfg.capacity = vec![elastic_spec()];
        let mut w = World::new(cfg, elastic_setups());
        w.run_until(120.0);
        // The wave saturated the committed server: standbys activated.
        assert!(w.scale_events > 0, "no scale events during the wave");
        assert!(
            w.node(2).online || w.node(3).online,
            "no standby came online under load"
        );
        // After the wave the elastic replicas drain, retire, and stay off.
        w.run_until(400.0);
        assert!(
            !w.node(2).online && !w.node(3).online,
            "standbys never retired after the wave"
        );
        assert!(w.node(1).online, "committed member must stay online");
        // Node-hours reflect elasticity: committed ~400 s, elastic less.
        assert!(w.node_seconds_online(1) > 390.0);
        for i in [2usize, 3] {
            let secs = w.node_seconds_online(i);
            assert!(
                secs > 0.0 && secs < 300.0,
                "standby {i} online {secs}s of 400"
            );
        }
        // Holding costs were assessed and burned from balances.
        assert!(w.capacity_credits_charged > 0);
        assert_eq!(w.capacity_groups().len(), 1);
    }

    #[test]
    fn static_capacity_spec_replays_capacity_free_trace() {
        // An inert static declaration must not perturb the event sequence
        // in any observable way — the full-config-level twin of this check
        // lives in rust/tests/replay_equivalence.rs.
        let fingerprint = |with_static: bool| {
            let mut cfg = WorldConfig { seed: 11, ..Default::default() };
            if with_static {
                cfg.capacity = vec![CapacityGroupSpec {
                    label: "g".into(),
                    region: 0,
                    members: vec![0, 1, 2, 3],
                    standby: vec![],
                    cfg: CapacityConfig::default(),
                }];
            }
            let mut w = World::new(cfg, setup_uniform(4, 3.0));
            w.run_until(300.0);
            (
                w.recorder.len(),
                (w.recorder.mean_latency() * 1e9) as u64,
                w.messages_sent,
                w.events_processed,
                w.scale_events,
                w.credit_totals()
                    .iter()
                    .map(|c| (c * 1e6) as u64)
                    .collect::<Vec<_>>(),
            )
        };
        assert_eq!(fingerprint(false), fingerprint(true));
    }

    #[test]
    fn node_hours_accounting_tracks_availability() {
        let mut w =
            World::new(WorldConfig::default(), setup_uniform(3, 1e12));
        w.schedule_leave(1, 50.0);
        w.schedule_join(1, 150.0);
        w.run_until(200.0);
        assert!((w.node_seconds_online(0) - 200.0).abs() < 1e-9);
        assert!((w.node_seconds_online(1) - 100.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn capacity_member_out_of_range_panics() {
        let mut cfg = WorldConfig::default();
        cfg.capacity = vec![CapacityGroupSpec {
            label: "g".into(),
            region: 0,
            members: vec![7],
            standby: vec![],
            cfg: CapacityConfig::default(),
        }];
        World::new(cfg, setup_uniform(2, 5.0));
    }

    #[test]
    #[should_panic(expected = "scale_down_util")]
    fn capacity_inverted_thresholds_panic() {
        let mut cfg = WorldConfig::default();
        cfg.capacity = vec![CapacityGroupSpec {
            label: "g".into(),
            region: 0,
            members: vec![0],
            standby: vec![],
            cfg: CapacityConfig {
                scale_up_util: 0.3,
                scale_down_util: 0.6,
                ..Default::default()
            },
        }];
        World::new(cfg, setup_uniform(2, 5.0));
    }

    /// Tracing is purely observational: enabling it changes no event,
    /// message, credit, or RNG draw, while the flight recorder and the
    /// metrics registry fill up alongside.
    #[test]
    fn observability_is_replay_neutral_and_populates_recorder() {
        let run = |obs: ObservabilityConfig| {
            let cfg = WorldConfig {
                seed: 9,
                observability: obs,
                ..Default::default()
            };
            let mut w = World::new(cfg, setup_uniform(4, 3.0));
            w.run_until(300.0);
            w
        };
        let off = run(ObservabilityConfig::default());
        let on = run(ObservabilityConfig {
            enabled: true,
            ..Default::default()
        });
        let fp = |w: &World| {
            (
                w.recorder.len(),
                (w.recorder.mean_latency() * 1e9) as u64,
                w.messages_sent,
                w.bytes_sent,
                w.events_processed,
                w.credit_totals()
                    .iter()
                    .map(|c| (c * 1e6) as u64)
                    .collect::<Vec<_>>(),
            )
        };
        assert_eq!(fp(&off), fp(&on));
        // Disabled leaves everything empty.
        assert!(off.registry().is_empty());
        assert!(off.span_trees().is_empty());
        // Enabled records spans and mirrors the world counters.
        let trees = on.span_trees();
        assert!(!trees.is_empty(), "no span trees recorded");
        assert!(trees.iter().any(|t| {
            let k = t.kinds();
            k.contains(&SpanKind::Admit) && k.contains(&SpanKind::Settle)
        }));
        let reg = on.registry();
        assert!(!reg.is_empty());
        let events = reg.get("events_processed", &[]).expect("metric");
        assert_eq!(events.value, on.events_processed as f64);
        assert!(!events.series.is_empty(), "never sampled");
        let done = reg.get("requests_completed", &[]).expect("metric");
        assert_eq!(done.value, on.recorder.user_records().count() as f64);
        // The trace JSON export is well-formed and non-trivial.
        let j = on.trace_json();
        let arr = j.get("traceEvents").as_arr().expect("traceEvents array");
        assert!(arr.len() > 10, "only {} trace events", arr.len());
    }

    /// `sample_rate` thins traced requests deterministically without
    /// touching the simulation, and a tiny ring drops oldest-first while
    /// counting what it shed.
    #[test]
    fn observability_sampling_and_ring_bounds() {
        let run = |obs: ObservabilityConfig| {
            let cfg = WorldConfig {
                seed: 9,
                observability: obs,
                ..Default::default()
            };
            let mut w = World::new(cfg, setup_uniform(4, 3.0));
            w.run_until(300.0);
            w
        };
        let full = run(ObservabilityConfig {
            enabled: true,
            ..Default::default()
        });
        let thin = run(ObservabilityConfig {
            enabled: true,
            sample_rate: 0.2,
            ..Default::default()
        });
        assert_eq!(full.events_processed, thin.events_processed);
        let (nf, nt) = (full.span_trees().len(), thin.span_trees().len());
        assert!(
            nt < nf && nt > 0,
            "sampled {nt} of {nf} traces at rate 0.2"
        );
        // Same seed, same requests: the sampled set is reproducible.
        let again = run(ObservabilityConfig {
            enabled: true,
            sample_rate: 0.2,
            ..Default::default()
        });
        assert_eq!(again.span_trees().len(), nt);
        // A tiny ring stays bounded and reports drops.
        let tiny = run(ObservabilityConfig {
            enabled: true,
            ring_capacity: 16,
            ..Default::default()
        });
        assert_eq!(tiny.events_processed, full.events_processed);
        let mut dropped = 0u64;
        for i in 0..tiny.num_nodes() {
            let fr = tiny.node(i).flight_recorder();
            assert!(fr.len() <= 16);
            dropped += fr.dropped();
        }
        assert!(dropped > 0, "tiny ring never overflowed");
    }

    /// An all-honest world with defenses armed stays deterministic, never
    /// punishes anyone, and pays for receipts in bytes only.
    #[test]
    fn defended_honest_world_is_deterministic_and_punishes_nobody() {
        let run = |defenses: DefenseConfig| {
            let cfg = WorldConfig {
                seed: 13,
                defenses,
                ..Default::default()
            };
            let mut w = World::new(cfg, setup_uniform(4, 3.0));
            w.run_until(300.0);
            w
        };
        let armed = DefenseConfig { enabled: true, ..Default::default() };
        let a = run(armed);
        let b = run(armed);
        let fp = |w: &World| {
            (
                w.recorder.len(),
                (w.recorder.mean_latency() * 1e9) as u64,
                w.messages_sent,
                w.bytes_sent,
                w.events_processed,
                w.credit_totals()
                    .iter()
                    .map(|c| (c * 1e6) as u64)
                    .collect::<Vec<_>>(),
            )
        };
        assert_eq!(fp(&a), fp(&b), "defended world must replay from seed");
        // Honest receipts all verify; nobody is quarantined.
        for i in 0..a.num_nodes() {
            let s = &a.node(i).stats;
            assert_eq!(s.receipt_rejects, 0, "node {i} rejected receipts");
            assert_eq!(s.quarantines, 0, "node {i} quarantined a peer");
            assert_eq!(s.rtts_rejected, 0, "node {i} saw junk rtts");
        }
        assert!(!a.recorder.is_empty(), "no requests completed");
        // Receipts and reputation rows ride the existing messages: same
        // message count as the undefended twin, strictly more bytes.
        let off = run(DefenseConfig::default());
        assert_eq!(a.messages_sent, off.messages_sent);
        assert!(
            a.bytes_sent > off.bytes_sent,
            "receipts must cost wire bytes: {} vs {}",
            a.bytes_sent,
            off.bytes_sent
        );
    }
}
