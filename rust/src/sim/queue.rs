//! Calendar-queue event scheduler for the simulation core.
//!
//! The seed `World` kept every pending event in one
//! `BinaryHeap<Reverse<Queued>>`: O(log n) per push/pop with n = every
//! arrival of the whole workload (pre-pushed at construction). At 10k
//! nodes that heap holds hundreds of thousands of entries and the log
//! factor — plus the cache misses of a pointer-hopping sift — dominates
//! the event loop. [`EventQueue`] replaces it with a classic calendar
//! queue (Brown 1988): a ring of `NSLOTS` time buckets of `WIDTH` virtual
//! seconds each, a lazily advancing cursor, and an overflow heap for
//! entries beyond the ring's horizon. Near-term events — the vast
//! majority — cost O(1) amortized to file and pop from a tiny per-bucket
//! heap.
//!
//! ## Ordering contract (the replay-critical part)
//!
//! Pop order is **exactly** the old heap's order: lexicographic
//! `(time, seq)` where `seq` is a per-queue counter incremented on every
//! push. Two properties make the equivalence exact, not approximate:
//!
//! * **Tie-breaking**: equal-`(t, seq)` entries cannot exist — `seq` is
//!   strictly increasing, so every entry's key is unique and simultaneous
//!   events pop in push order (FIFO), exactly as `Reverse<Queued>` did.
//! * **Monotone bucketing**: the bucket function `t ↦ (t / WIDTH) as u64`
//!   is monotone non-decreasing in `t` (division by a positive constant,
//!   then truncation), so an entry in a later bucket never has a smaller
//!   `t` than one in an earlier bucket — even at bucket-boundary rounding,
//!   order across buckets is preserved and order *within* a bucket is the
//!   old comparator verbatim.
//!
//! Entries timed in the past (before the cursor) are filed into the
//! *current* bucket and pop immediately — again matching the heap, which
//! surfaces the global minimum regardless of when it was pushed.
//! Non-finite times degrade gracefully: `+∞` saturates to the last bucket
//! and pops after everything finite, in seq order, as the old
//! `partial_cmp(..).unwrap_or(Equal)` comparator arranged.
//!
//! The equivalence is proven wholesale by the same-tape ordering oracle
//! in `rust/tests/event_queue_oracle.rs`, which replays randomized
//! push/pop tapes against a reference `BinaryHeap` with the seed's
//! comparator and asserts identical pop sequences.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::types::Time;

/// Virtual seconds per calendar bucket. Sized so one bucket holds a
/// handful of events at fleet scale: WAN latencies are 0.5–125 ms and
/// node ticks are 1 s apart, so 50 ms buckets keep per-bucket heaps tiny
/// without making cursor sweeps over idle stretches expensive.
const WIDTH: f64 = 0.05;
/// Ring size. `NSLOTS * WIDTH` ≈ 205 virtual seconds of horizon; events
/// beyond it (pre-pushed arrival traces, far-future churn) wait in the
/// overflow heap and migrate into the ring as the cursor approaches.
const NSLOTS: usize = 4096;

struct Entry<T> {
    t: Time,
    seq: u64,
    item: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl<T> Eq for Entry<T> {}
impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Entry<T> {
    // The seed comparator, verbatim: time then push sequence. `seq` is
    // unique per queue, so this is a total order with no real ties.
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t
            .partial_cmp(&other.t)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(self.seq.cmp(&other.seq))
    }
}

/// A calendar queue yielding `(Time, T)` in exact `(time, seq)` order.
/// See the module docs for the ordering contract.
pub struct EventQueue<T> {
    /// The ring: slot `b % NSLOTS` holds bucket `b` for
    /// `b ∈ [cursor, cursor + NSLOTS)`.
    slots: Vec<BinaryHeap<Reverse<Entry<T>>>>,
    /// Entries whose bucket lies beyond the ring's current horizon.
    overflow: BinaryHeap<Reverse<Entry<T>>>,
    /// Absolute bucket index of the ring's current position. Never
    /// decreases; past-time pushes clamp into it.
    cursor: u64,
    /// Entries currently in `slots` (vs `overflow`).
    in_slots: usize,
    len: usize,
    seq: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> EventQueue<T> {
        EventQueue {
            slots: (0..NSLOTS).map(|_| BinaryHeap::new()).collect(),
            overflow: BinaryHeap::new(),
            cursor: 0,
            in_slots: 0,
            len: 0,
            seq: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Absolute bucket of time `t`. Monotone in `t`; saturates at
    /// `u64::MAX` for `+∞` (the `as` cast's defined saturating behaviour),
    /// and negative/NaN times land in bucket 0.
    fn bucket_of(t: Time) -> u64 {
        if t <= 0.0 {
            0
        } else {
            (t / WIDTH) as u64
        }
    }

    /// Schedule `item` at time `t`. Assigns the next sequence number, so
    /// push order is the tiebreak for simultaneous events.
    pub fn push(&mut self, t: Time, item: T) {
        self.seq += 1;
        let e = Entry { t, seq: self.seq, item };
        // Past-time entries clamp into the current bucket: they must pop
        // immediately, and the in-bucket heap orders them ahead of
        // everything later-timed.
        let b = Self::bucket_of(t).max(self.cursor);
        if b - self.cursor < NSLOTS as u64 {
            self.slots[(b % NSLOTS as u64) as usize].push(Reverse(e));
            self.in_slots += 1;
        } else {
            self.overflow.push(Reverse(e));
        }
        self.len += 1;
    }

    /// Remove and return the earliest entry by `(time, seq)`.
    pub fn pop(&mut self) -> Option<(Time, T)> {
        if !self.settle() {
            return None;
        }
        let slot = &mut self.slots[(self.cursor % NSLOTS as u64) as usize];
        let Reverse(e) = slot.pop().expect("settled on a non-empty bucket");
        self.in_slots -= 1;
        self.len -= 1;
        Some((e.t, e.item))
    }

    /// Time of the earliest entry without removing it. Takes `&mut self`
    /// because locating the next entry may advance the ring cursor and
    /// migrate overflow entries — both invisible to pop order.
    pub fn peek_time(&mut self) -> Option<Time> {
        if !self.settle() {
            return None;
        }
        let slot = &self.slots[(self.cursor % NSLOTS as u64) as usize];
        slot.peek().map(|Reverse(e)| e.t)
    }

    /// Advance the cursor until the current bucket's top entry is due
    /// (its natural bucket ≤ cursor). Returns false when the queue is
    /// empty. On return-true, the current slot's heap top is the global
    /// `(time, seq)` minimum.
    fn settle(&mut self) -> bool {
        if self.len == 0 {
            return false;
        }
        loop {
            let slot = &self.slots[(self.cursor % NSLOTS as u64) as usize];
            if let Some(Reverse(top)) = slot.peek() {
                if Self::bucket_of(top.t) <= self.cursor {
                    return true;
                }
            }
            if self.in_slots == 0 {
                // Ring fully drained: jump straight to the overflow
                // minimum's bucket instead of sweeping empty slots.
                let Some(Reverse(top)) = self.overflow.peek() else {
                    unreachable!("len > 0 with empty ring and overflow");
                };
                self.cursor = self.cursor.max(Self::bucket_of(top.t));
            } else {
                self.cursor += 1;
            }
            self.drain_overflow();
        }
    }

    /// Move every overflow entry whose bucket has entered the ring's
    /// window into its slot. Called after each cursor move so bucket
    /// `cursor + NSLOTS - 1` is populated before the cursor can reach it.
    fn drain_overflow(&mut self) {
        while let Some(Reverse(top)) = self.overflow.peek() {
            // Overflow entries always have bucket ≥ cursor: they entered
            // with bucket ≥ (push-time cursor + NSLOTS) and migrate the
            // first time the window reaches them.
            let b = Self::bucket_of(top.t);
            if b.saturating_sub(self.cursor) >= NSLOTS as u64 {
                break;
            }
            let Reverse(e) = self.overflow.pop().expect("peeked");
            self.slots[(b % NSLOTS as u64) as usize].push(Reverse(e));
            self.in_slots += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((3.0, "c")));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn equal_times_pop_fifo_by_seq() {
        // The documented tie rule: same t, push order wins. Equal (t, seq)
        // keys cannot exist — seq is strictly increasing per push.
        let mut q = EventQueue::new();
        for i in 0..100u32 {
            q.push(5.0, i);
        }
        for i in 0..100u32 {
            assert_eq!(q.pop(), Some((5.0, i)));
        }
    }

    #[test]
    fn past_time_push_pops_immediately() {
        let mut q = EventQueue::new();
        q.push(50.0, "future");
        assert_eq!(q.pop(), Some((50.0, "future")));
        // Cursor is now deep in the ring; a past-time push still pops
        // next, ahead of anything later.
        q.push(60.0, "later");
        q.push(10.0, "past");
        assert_eq!(q.pop(), Some((10.0, "past")));
        assert_eq!(q.pop(), Some((60.0, "later")));
    }

    #[test]
    fn far_future_overflow_round_trips() {
        let mut q = EventQueue::new();
        let horizon = WIDTH * NSLOTS as f64;
        q.push(horizon * 3.0, "far");
        q.push(horizon * 10.0, "farther");
        q.push(0.5, "near");
        assert_eq!(q.pop(), Some((0.5, "near")));
        assert_eq!(q.pop(), Some((horizon * 3.0, "far")));
        assert_eq!(q.pop(), Some((horizon * 10.0, "farther")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn peek_time_matches_pop_and_does_not_consume() {
        let mut q = EventQueue::new();
        q.push(7.25, 1u8);
        q.push(2.5, 2u8);
        assert_eq!(q.peek_time(), Some(2.5));
        assert_eq!(q.peek_time(), Some(2.5));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some((2.5, 2)));
        assert_eq!(q.peek_time(), Some(7.25));
    }

    #[test]
    fn infinity_pops_last_in_seq_order() {
        let mut q = EventQueue::new();
        q.push(f64::INFINITY, "inf1");
        q.push(1.0, "one");
        q.push(f64::INFINITY, "inf2");
        assert_eq!(q.pop(), Some((1.0, "one")));
        assert_eq!(q.pop().map(|(_, v)| v), Some("inf1"));
        assert_eq!(q.pop().map(|(_, v)| v), Some("inf2"));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn bucket_boundary_times_stay_ordered() {
        let mut q = EventQueue::new();
        // Exact multiples of WIDTH sit on bucket edges; order must hold.
        let times: Vec<f64> =
            (0..200).map(|i| i as f64 * WIDTH).rev().collect();
        for &t in &times {
            q.push(t, t);
        }
        let mut last = f64::NEG_INFINITY;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last, "pop order regressed: {t} after {last}");
            last = t;
        }
    }

    #[test]
    fn interleaved_push_pop_stays_monotone() {
        let mut q = EventQueue::new();
        let mut out = Vec::new();
        for round in 0..50u64 {
            let base = round as f64 * 1.7;
            q.push(base + 0.3, round * 10);
            q.push(base + 900.0, round * 10 + 1);
            q.push(base, round * 10 + 2);
            let (t, _) = q.pop().unwrap();
            out.push(t);
        }
        while let Some((t, _)) = q.pop() {
            out.push(t);
        }
        for w in out.windows(2) {
            assert!(w[0] <= w[1], "non-monotone: {} then {}", w[0], w[1]);
        }
        assert_eq!(out.len(), 150);
    }
}
