//! Streaming-session semantics: disaggregated prefill/decode and
//! KV-affine dispatch, behind one declarative `streaming` config block.
//!
//! The paper's requests are atomic point events; the scheduling problem
//! real participants face is token streams — TTFT vs end-to-end SLOs,
//! compute-bound (delegable) prefill vs KV-memory-bound (sticky) decode,
//! and multi-turn sessions whose KV residency makes re-dispatch expensive.
//! This module holds the config block that arms the whole stack:
//!
//! * `workload::SessionProfile` — multi-turn session generation with
//!   per-turn TTFT deadlines;
//! * `backend::SimBackend` split-pool admission
//!   ([`Backend::set_prefill_slots`](crate::backend::Backend::set_prefill_slots));
//! * `coordinator::dispatch` KV-affinity (probe the session's resident
//!   node with probability [`StreamingConfig::affinity_bonus`]; a
//!   re-dispatch ships the session KV as a `Message::KvTransfer` sized by
//!   [`StreamingConfig::kv_bytes_per_token`] — a real queue event priced
//!   over `Topology` bandwidth and counted in `World::kv_transfer_{count,bytes}`);
//! * the executor-side churn NACK (`Message::ExecAbort`) that turns an
//!   honest executor's Leave into prompt local fallback at the requester
//!   instead of a response-timeout reputation strike.
//!
//! With `enabled: false` (the default) every hook above is inert and
//! replay fingerprints are bit-identical to the pre-streaming tree
//! (`rust/tests/replay_equivalence.rs`). See `docs/streaming.md`.

/// Declarative `streaming` config block knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StreamingConfig {
    /// Master switch. `false` (the default) keeps dispatch session-blind,
    /// admission unified, and the churn NACK off — the pre-streaming
    /// replay stream, draw for draw.
    pub enabled: bool,
    /// Probability that a session turn is routed to the session's KV
    /// home instead of a fresh stake-weighted draw. 1.0 = fully affine,
    /// 0.0 = affinity-blind (the bench baseline).
    pub affinity_bonus: f64,
    /// KV-cache bytes per resident context token — sizes the
    /// `KvTransfer` message a re-dispatch ships (fp16 KV for an ~8B
    /// model is ~160 kB/token; see `backend::Profile::kv_gb_per_seq`).
    pub kv_bytes_per_token: f64,
    /// Prefill-pool cap installed on each node's backend (split-pool
    /// admission). 0 means "same as the profile's `max_batch`".
    pub prefill_slots: usize,
    /// Executor-side churn NACK: on Leave, an executor NACKs its
    /// in-flight delegations (`Message::ExecAbort`) so requesters fall
    /// back locally at once instead of waiting out the response timeout
    /// (and filing a Byzantine-grade `RepEvent::Timeout` strike).
    pub churn_nack: bool,
}

impl Default for StreamingConfig {
    fn default() -> Self {
        StreamingConfig {
            enabled: false,
            affinity_bonus: 1.0,
            kv_bytes_per_token: 160_000.0,
            prefill_slots: 0,
            churn_nack: true,
        }
    }
}

impl StreamingConfig {
    /// Validate, returning a descriptive error (the config-parser path).
    pub fn check(&self) -> Result<(), String> {
        if !self.affinity_bonus.is_finite()
            || !(0.0..=1.0).contains(&self.affinity_bonus)
        {
            return Err(format!(
                "affinity_bonus must be a finite fraction in [0, 1], got {}",
                self.affinity_bonus
            ));
        }
        if !self.kv_bytes_per_token.is_finite() || self.kv_bytes_per_token < 0.0
        {
            return Err(format!(
                "kv_bytes_per_token must be finite and >= 0, got {}",
                self.kv_bytes_per_token
            ));
        }
        if !self.enabled
            && (self.affinity_bonus != 1.0 || self.prefill_slots != 0)
        {
            // Guard against configs that *look* armed but aren't: live
            // knobs on a disabled block are almost certainly a mistake.
            return Err(
                "streaming knobs set but enabled is false; set enabled: true \
                 or drop the block"
                    .into(),
            );
        }
        Ok(())
    }

    /// Panicking twin of [`check`](Self::check) for programmatic configs.
    pub fn validate(&self) {
        if let Err(e) = self.check() {
            panic!("StreamingConfig: {e}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_disabled_and_valid() {
        let cfg = StreamingConfig::default();
        assert!(!cfg.enabled);
        assert!(cfg.check().is_ok());
    }

    #[test]
    fn check_rejects_bad_knobs() {
        let bad_bonus = StreamingConfig {
            enabled: true,
            affinity_bonus: 1.5,
            ..Default::default()
        };
        assert!(bad_bonus.check().is_err());
        let nan_bonus = StreamingConfig {
            enabled: true,
            affinity_bonus: f64::NAN,
            ..Default::default()
        };
        assert!(nan_bonus.check().is_err());
        let neg_kv = StreamingConfig {
            enabled: true,
            kv_bytes_per_token: -1.0,
            ..Default::default()
        };
        assert!(neg_kv.check().is_err());
        let armed_but_off = StreamingConfig {
            enabled: false,
            prefill_slots: 4,
            ..Default::default()
        };
        assert!(armed_but_off.check().is_err());
    }

    #[test]
    #[should_panic(expected = "affinity_bonus")]
    fn validate_panics() {
        StreamingConfig {
            enabled: true,
            affinity_bonus: -0.1,
            ..Default::default()
        }
        .validate();
    }
}
