//! Geo-distributed WAN topology: regions, link model, and scheduled
//! partitions.
//!
//! The seed simulator modelled the network as one flat uniform latency range
//! — every hop cost the same whether peers shared a rack or an ocean. This
//! subsystem makes WAN structure first-class:
//!
//! * named **regions** with per-node region assignment;
//! * a per-region-pair **link matrix** ([`LinkProfile`]: uniform base
//!   latency, exponential jitter tail, finite bandwidth for payload-sized
//!   transfer cost);
//! * a scheduled **scenario layer** ([`LinkEvent`]: degrade / partition /
//!   heal between region pairs at given times), applied by the simulator as
//!   ordinary world events so replays stay deterministic.
//!
//! [`Topology::single_region`] reproduces the flat model *bit-for-bit*: one
//! region whose intra link draws exactly one uniform sample per message with
//! the same guard the old `World::sample_latency` used, no jitter draw and
//! no bandwidth term — so every pre-topology bench and test replays
//! identically. Multi-region worlds are built with [`Topology::builder`] or
//! parsed from the declarative `"topology"` config block (`config` module).

use crate::types::Time;
use crate::util::intern::Interner;
use crate::util::rng::Rng;

/// Index into a topology's region table.
///
/// Region tags are interned at construction ([`Interner`]): hot paths
/// carry this dense index, and the human-readable name is resolved only
/// at reporting boundaries via [`Topology::region_name`] — which panics
/// loudly on an id the table never issued.
pub type RegionId = usize;

/// Behaviour of one region-pair link (stored symmetrically).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkProfile {
    /// Uniform one-way base latency range in seconds.
    pub latency: (f64, f64),
    /// Mean of an additional exponential jitter term in seconds
    /// (0 disables the draw entirely — important for flat-model replay).
    pub jitter: f64,
    /// Link bandwidth in bytes/second; `f64::INFINITY` disables the
    /// payload-size-dependent transfer term.
    pub bandwidth: f64,
    /// A partitioned link silently drops every message.
    pub partitioned: bool,
}

impl LinkProfile {
    pub fn new(lo: Time, hi: Time) -> LinkProfile {
        LinkProfile {
            latency: (lo, hi),
            jitter: 0.0,
            bandwidth: f64::INFINITY,
            partitioned: false,
        }
    }

    pub fn with_jitter(mut self, mean_s: f64) -> LinkProfile {
        self.jitter = mean_s;
        self
    }

    pub fn with_bandwidth_mbps(mut self, mbps: f64) -> LinkProfile {
        self.bandwidth = mbps * 1e6 / 8.0;
        self
    }

    /// Expected one-way delay for a small message (dispatch scoring).
    pub fn expected_latency(&self) -> f64 {
        (self.latency.0 + self.latency.1) / 2.0 + self.jitter
    }

    /// Panics with a descriptive message on an invalid profile.
    fn validate(&self, what: &str) {
        let (lo, hi) = self.latency;
        assert!(
            lo.is_finite() && hi.is_finite() && lo >= 0.0,
            "{what}: latency bounds must be finite and non-negative, got ({lo}, {hi})"
        );
        assert!(lo <= hi, "{what}: latency lo {lo} > hi {hi}");
        assert!(
            self.jitter >= 0.0 && self.jitter.is_finite(),
            "{what}: jitter must be finite and >= 0, got {}",
            self.jitter
        );
        assert!(
            self.bandwidth > 0.0,
            "{what}: bandwidth must be > 0 (use f64::INFINITY for unconstrained), got {}",
            self.bandwidth
        );
    }

    /// One-way delay for `bytes` over this link, or `None` if partitioned.
    ///
    /// RNG discipline (replay compatibility): exactly one uniform draw when
    /// `lo < hi`, none when `lo == hi`; one extra exponential draw only when
    /// `jitter > 0`. The bandwidth term is deterministic.
    fn sample(&self, bytes: usize, rng: &mut Rng) -> Option<Time> {
        if self.partitioned {
            return None;
        }
        let (lo, hi) = self.latency;
        let mut d = if hi <= lo { lo } else { rng.range_f64(lo, hi) };
        if self.jitter > 0.0 {
            d += rng.exp(1.0 / self.jitter);
        }
        if self.bandwidth.is_finite() && bytes > 0 {
            d += bytes as f64 / self.bandwidth;
        }
        Some(d)
    }
}

/// What happens to a region-pair link at a scheduled time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LinkChange {
    /// Multiply base latency by `latency_factor` and bandwidth by
    /// `bandwidth_factor` (congestion, cable reroute), in **both**
    /// directions.
    Degrade {
        latency_factor: f64,
        bandwidth_factor: f64,
    },
    /// Like [`Degrade`](LinkChange::Degrade), but applied only to the
    /// `a -> b` direction. Real congestion is routinely one-way (a
    /// saturated egress, an asymmetric BGP detour); the symmetric variant
    /// silently over-degraded the return path, which hid exactly the
    /// asymmetries the live latency estimator exists to catch.
    DegradeDirectional {
        latency_factor: f64,
        bandwidth_factor: f64,
    },
    /// Drop all traffic on the link.
    Partition,
    /// Restore the link to its pristine (build-time) profile.
    Heal,
}

/// A scheduled change to the link between regions `a` and `b`. All
/// changes apply to both directions except
/// [`LinkChange::DegradeDirectional`], which touches only `a -> b`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkEvent {
    pub at: Time,
    pub a: RegionId,
    pub b: RegionId,
    pub change: LinkChange,
}

/// The world's WAN structure: regions, current link state, node placement
/// and the scenario schedule. Cheap to clone (region count is small).
#[derive(Debug, Clone)]
pub struct Topology {
    /// Interned region-name table: `RegionId` = dense intern id.
    regions: Interner,
    /// Current link state, row-major `[src * n + dst]`.
    links: Vec<LinkProfile>,
    /// Pristine copy of `links` for `LinkChange::Heal`.
    base: Vec<LinkProfile>,
    /// Region of node `i`; empty means "every node in region 0".
    node_region: Vec<RegionId>,
    /// Scenario schedule, sorted by time.
    events: Vec<LinkEvent>,
}

impl Topology {
    /// The flat-model equivalent: one region whose intra-region link is the
    /// given uniform latency range. Replays bit-identically to the seed's
    /// `World::sample_latency`.
    pub fn single_region(latency: (Time, Time)) -> Topology {
        let mut regions = Interner::new();
        regions.intern("local");
        Topology {
            regions,
            links: vec![LinkProfile::new(latency.0, latency.1)],
            base: vec![LinkProfile::new(latency.0, latency.1)],
            node_region: Vec::new(),
            events: Vec::new(),
        }
    }

    pub fn builder() -> TopologyBuilder {
        TopologyBuilder::new()
    }

    pub fn num_regions(&self) -> usize {
        self.regions.len()
    }

    /// Resolve a region id to its name — a reporting-boundary operation.
    /// Panics on an unknown id (see [`Interner::resolve`]): silently
    /// defaulting would let a corrupted region index reach reports.
    pub fn region_name(&self, r: RegionId) -> &str {
        self.regions.resolve(r as u32)
    }

    pub fn region_index(&self, name: &str) -> Option<RegionId> {
        self.regions.lookup(name).map(|id| id as RegionId)
    }

    /// The interned region-name table itself (export paths that want to
    /// resolve many ids without going through `region_name` one by one).
    pub fn region_table(&self) -> &Interner {
        &self.regions
    }

    /// Region of node `i` (region 0 when unassigned).
    pub fn region_of(&self, node: usize) -> RegionId {
        self.node_region.get(node).copied().unwrap_or(0)
    }

    pub fn node_regions(&self) -> &[RegionId] {
        &self.node_region
    }

    pub fn link(&self, a: RegionId, b: RegionId) -> &LinkProfile {
        &self.links[a * self.regions.len() + b]
    }

    pub fn is_partitioned(&self, a: RegionId, b: RegionId) -> bool {
        self.link(a, b).partitioned
    }

    pub fn events(&self) -> &[LinkEvent] {
        &self.events
    }

    /// One-way delay for a `bytes`-sized message from node `src` to node
    /// `dst`, or `None` if the connecting link is currently partitioned.
    pub fn sample_delay(
        &self,
        src: usize,
        dst: usize,
        bytes: usize,
        rng: &mut Rng,
    ) -> Option<Time> {
        self.link(self.region_of(src), self.region_of(dst)).sample(bytes, rng)
    }

    /// Long-run expected one-way latency between every region pair, from the
    /// *pristine* link profiles (a static estimate — dispatch policies do
    /// not get oracle knowledge of live partitions or degradations).
    ///
    /// Since the live estimator landed (`crate::latency`) this matrix is
    /// only the **cold-start prior**: dispatch scores peers with measured
    /// EWMA estimates seeded from it, and decays back to it when
    /// observations go stale. Nothing on the request path reads it
    /// directly any more.
    pub fn expected_latency_matrix(&self) -> Vec<Vec<f64>> {
        let n = self.regions.len();
        (0..n)
            .map(|a| {
                (0..n)
                    .map(|b| self.base[a * n + b].expected_latency())
                    .collect()
            })
            .collect()
    }

    /// Apply scheduled event `idx` (both directions of the pair, except
    /// [`LinkChange::DegradeDirectional`] which touches only `a -> b`).
    /// The simulator calls this when virtual time reaches `events[idx].at`.
    pub fn apply_event(&mut self, idx: usize) {
        let ev = self.events[idx];
        let n = self.regions.len();
        // An intra-region event (a == b) names one link slot — don't apply
        // the mirrored direction to the same slot twice. A directional
        // degrade never mirrors at all.
        let mut directions = vec![(ev.a, ev.b)];
        let one_way = matches!(ev.change, LinkChange::DegradeDirectional { .. });
        if ev.a != ev.b && !one_way {
            directions.push((ev.b, ev.a));
        }
        for (a, b) in directions {
            let i = a * n + b;
            match ev.change {
                LinkChange::Degrade { latency_factor, bandwidth_factor }
                | LinkChange::DegradeDirectional {
                    latency_factor,
                    bandwidth_factor,
                } => {
                    // Degrade factors are relative to the *pristine*
                    // profile, not the current one: re-applying a "3x
                    // congestion" event re-asserts 3x, it does not compound
                    // to 9x (schedule a single event with the product to
                    // stack severities). The partitioned flag is left
                    // alone — degrading a partitioned link must not
                    // silently heal it.
                    let base = self.base[i];
                    let l = &mut self.links[i];
                    l.latency.0 = base.latency.0 * latency_factor;
                    l.latency.1 = base.latency.1 * latency_factor;
                    l.jitter = base.jitter * latency_factor;
                    l.bandwidth = base.bandwidth * bandwidth_factor;
                }
                LinkChange::Partition => self.links[i].partitioned = true,
                LinkChange::Heal => self.links[i] = self.base[i],
            }
        }
    }

    /// Validate the whole topology against a world of `num_nodes` nodes.
    /// Panics with a descriptive message on any inconsistency — silent
    /// misbehaviour (e.g. an inverted latency range) is worse than a crash
    /// at construction.
    pub fn validate(&self, num_nodes: usize) {
        let n = self.regions.len();
        assert!(n > 0, "topology: at least one region required");
        assert_eq!(
            self.links.len(),
            n * n,
            "topology: link matrix must be {n}x{n}"
        );
        for a in 0..n {
            for b in 0..n {
                let what = format!(
                    "topology link {} -> {}",
                    self.regions.resolve(a as u32),
                    self.regions.resolve(b as u32)
                );
                self.links[a * n + b].validate(&what);
                self.base[a * n + b].validate(&what);
            }
        }
        assert!(
            self.node_region.is_empty() || self.node_region.len() == num_nodes,
            "topology: {} node assignments for a {}-node world",
            self.node_region.len(),
            num_nodes
        );
        for (i, r) in self.node_region.iter().enumerate() {
            assert!(
                *r < n,
                "topology: node {i} assigned to unknown region index {r} \
                 ({n} regions)"
            );
        }
        for (i, ev) in self.events.iter().enumerate() {
            assert!(
                ev.a < n && ev.b < n,
                "topology: event {i} references unknown region index"
            );
            assert!(
                ev.at.is_finite() && ev.at >= 0.0,
                "topology: event {i} has invalid time {}",
                ev.at
            );
            if let LinkChange::Degrade { latency_factor, bandwidth_factor }
            | LinkChange::DegradeDirectional {
                latency_factor,
                bandwidth_factor,
            } = ev.change
            {
                assert!(
                    latency_factor > 0.0 && bandwidth_factor > 0.0,
                    "topology: event {i} degrade factors must be > 0"
                );
            }
        }
    }
}

/// Fluent construction of multi-region topologies (benches, config parser).
#[derive(Debug, Clone)]
pub struct TopologyBuilder {
    regions: Interner,
    intra_default: LinkProfile,
    inter_default: LinkProfile,
    overrides: Vec<(RegionId, RegionId, LinkProfile)>,
    node_region: Vec<RegionId>,
    events: Vec<LinkEvent>,
}

impl Default for TopologyBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl TopologyBuilder {
    pub fn new() -> TopologyBuilder {
        TopologyBuilder {
            regions: Interner::new(),
            // Datacenter-ish defaults; override per deployment.
            intra_default: LinkProfile::new(0.002, 0.010),
            inter_default: LinkProfile::new(0.040, 0.080),
            overrides: Vec::new(),
            node_region: Vec::new(),
            events: Vec::new(),
        }
    }

    /// Declare a region (intern order = declaration order).
    pub fn region(mut self, name: &str) -> Self {
        assert!(
            self.regions.lookup(name).is_none(),
            "topology builder: duplicate region '{name}'"
        );
        self.regions.intern(name);
        self
    }

    /// Default link profile within every region.
    pub fn default_intra(mut self, p: LinkProfile) -> Self {
        self.intra_default = p;
        self
    }

    /// Default link profile between every pair of distinct regions.
    pub fn default_inter(mut self, p: LinkProfile) -> Self {
        self.inter_default = p;
        self
    }

    fn region_id(&self, name: &str) -> RegionId {
        self.regions
            .lookup(name)
            .map(|id| id as RegionId)
            .unwrap_or_else(|| {
                panic!("topology builder: unknown region '{name}'")
            })
    }

    /// Override the (symmetric) link between two regions; `a == b` sets an
    /// intra-region link.
    pub fn link(mut self, a: &str, b: &str, p: LinkProfile) -> Self {
        let (ra, rb) = (self.region_id(a), self.region_id(b));
        self.overrides.push((ra, rb, p));
        self
    }

    /// Assign the next node (in `World` setup order) to `region`.
    pub fn node(mut self, region: &str) -> Self {
        let r = self.region_id(region);
        self.node_region.push(r);
        self
    }

    /// Assign `count` consecutive nodes to `region`.
    pub fn nodes(mut self, region: &str, count: usize) -> Self {
        let r = self.region_id(region);
        self.node_region.extend(std::iter::repeat(r).take(count));
        self
    }

    /// Schedule a link change between two regions at time `at`.
    pub fn event(
        mut self,
        a: &str,
        b: &str,
        at: Time,
        change: LinkChange,
    ) -> Self {
        let (ra, rb) = (self.region_id(a), self.region_id(b));
        self.events.push(LinkEvent { at, a: ra, b: rb, change });
        self
    }

    pub fn build(self) -> Topology {
        let n = self.regions.len();
        assert!(n > 0, "topology builder: no regions declared");
        let mut links = vec![self.inter_default; n * n];
        for a in 0..n {
            links[a * n + a] = self.intra_default;
        }
        for (a, b, p) in self.overrides {
            links[a * n + b] = p;
            links[b * n + a] = p;
        }
        let mut events = self.events;
        events.sort_by(|x, y| {
            x.at.partial_cmp(&y.at).expect("finite event times")
        });
        let t = Topology {
            regions: self.regions,
            base: links.clone(),
            links,
            node_region: self.node_region,
            events,
        };
        // Node-count-independent part of validation; `World::new` re-runs
        // the full check with the real node count.
        t.validate(t.node_region.len());
        t
    }
}

/// A realistic three-continent WAN preset (one-way latencies from public
/// inter-region RTT tables, halved): `us`, `eu`, `asia` with
/// `nodes_per_region` nodes each, assigned contiguously us..eu..asia.
pub fn three_region_wan(nodes_per_region: usize) -> TopologyBuilder {
    Topology::builder()
        .region("us")
        .region("eu")
        .region("asia")
        // Same-metro datacenter latency: sub-2ms, effectively free next to
        // the ocean links — so a latency penalty tuned to discriminate
        // between continents barely damps intra-region dispatch.
        .default_intra(
            LinkProfile::new(0.0005, 0.002).with_bandwidth_mbps(10_000.0),
        )
        .link(
            "us",
            "eu",
            LinkProfile::new(0.040, 0.055)
                .with_jitter(0.004)
                .with_bandwidth_mbps(400.0),
        )
        .link(
            "us",
            "asia",
            LinkProfile::new(0.075, 0.095)
                .with_jitter(0.006)
                .with_bandwidth_mbps(300.0),
        )
        .link(
            "eu",
            "asia",
            LinkProfile::new(0.100, 0.125)
                .with_jitter(0.008)
                .with_bandwidth_mbps(250.0),
        )
        .nodes("us", nodes_per_region)
        .nodes("eu", nodes_per_region)
        .nodes("asia", nodes_per_region)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_region() -> Topology {
        Topology::builder()
            .region("west")
            .region("east")
            .default_intra(LinkProfile::new(0.001, 0.002))
            .link("west", "east", LinkProfile::new(0.050, 0.060))
            .nodes("west", 2)
            .nodes("east", 2)
            .build()
    }

    #[test]
    fn single_region_matches_flat_sampler() {
        // The topology path must consume the identical RNG stream the old
        // flat `sample_latency` did: one uniform draw per message.
        let topo = Topology::single_region((0.02, 0.08));
        let mut a = Rng::new(99);
        let mut b = Rng::new(99);
        for _ in 0..1000 {
            let flat = a.range_f64(0.02, 0.08);
            let via = topo.sample_delay(0, 1, 512, &mut b).unwrap();
            assert_eq!(flat, via);
        }
    }

    #[test]
    fn degenerate_range_consumes_no_draw() {
        let topo = Topology::single_region((0.05, 0.05));
        let mut rng = Rng::new(1);
        let before = rng.clone().next_u64();
        assert_eq!(topo.sample_delay(0, 1, 0, &mut rng), Some(0.05));
        assert_eq!(rng.next_u64(), before, "no RNG draw for lo == hi");
    }

    #[test]
    fn inter_region_slower_than_intra() {
        let topo = two_region();
        let mut rng = Rng::new(5);
        for _ in 0..200 {
            let intra = topo.sample_delay(0, 1, 0, &mut rng).unwrap();
            let inter = topo.sample_delay(0, 2, 0, &mut rng).unwrap();
            assert!(intra < inter, "intra {intra} !< inter {inter}");
        }
    }

    #[test]
    fn bandwidth_adds_transfer_cost() {
        let p = LinkProfile::new(0.01, 0.01).with_bandwidth_mbps(8.0); // 1 MB/s
        let mut rng = Rng::new(2);
        let d = p.sample(500_000, &mut rng).unwrap();
        assert!((d - 0.51).abs() < 1e-9, "0.01 base + 0.5 transfer, got {d}");
    }

    #[test]
    fn jitter_is_additive_and_optional() {
        let base = LinkProfile::new(0.01, 0.01);
        let jittery = base.with_jitter(0.005);
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let d = jittery.sample(0, &mut rng).unwrap();
            assert!(d >= 0.01);
        }
        // Mean jitter shows up in the expectation.
        assert!(jittery.expected_latency() > base.expected_latency());
    }

    #[test]
    fn partition_drops_and_heal_restores() {
        let mut topo = Topology::builder()
            .region("a")
            .region("b")
            .link("a", "b", LinkProfile::new(0.05, 0.06))
            .node("a")
            .node("b")
            .event("a", "b", 10.0, LinkChange::Partition)
            .event("a", "b", 20.0, LinkChange::Heal)
            .build();
        let mut rng = Rng::new(4);
        assert!(topo.sample_delay(0, 1, 0, &mut rng).is_some());
        topo.apply_event(0);
        assert!(topo.is_partitioned(0, 1));
        assert!(topo.is_partitioned(1, 0), "partitions are symmetric");
        assert!(topo.sample_delay(0, 1, 0, &mut rng).is_none());
        assert!(topo.sample_delay(1, 0, 0, &mut rng).is_none());
        // Intra traffic unaffected.
        assert!(topo.sample_delay(0, 0, 0, &mut rng).is_some());
        topo.apply_event(1);
        assert!(!topo.is_partitioned(0, 1));
        assert!(topo.sample_delay(0, 1, 0, &mut rng).is_some());
    }

    #[test]
    fn degrade_scales_latency_and_heal_undoes_it() {
        let mut topo = Topology::builder()
            .region("a")
            .region("b")
            .link("a", "b", LinkProfile::new(0.040, 0.050))
            .event(
                "a",
                "b",
                5.0,
                LinkChange::Degrade { latency_factor: 3.0, bandwidth_factor: 0.5 },
            )
            .event("a", "b", 9.0, LinkChange::Heal)
            .build();
        topo.apply_event(0);
        let l = topo.link(0, 1);
        assert!((l.latency.0 - 0.120).abs() < 1e-12);
        assert!((l.latency.1 - 0.150).abs() < 1e-12);
        topo.apply_event(1);
        let l = topo.link(0, 1);
        assert!((l.latency.0 - 0.040).abs() < 1e-12);
        assert!((l.latency.1 - 0.050).abs() < 1e-12);
    }

    #[test]
    fn repeated_degrades_do_not_compound() {
        // Degrade semantics are factor-vs-pristine: two "3x congestion"
        // events leave the link at 3x, not 9x, and a degrade on a
        // partitioned link does not heal the partition.
        let mut topo = Topology::builder()
            .region("a")
            .region("b")
            .link(
                "a",
                "b",
                LinkProfile::new(0.040, 0.050)
                    .with_jitter(0.004)
                    .with_bandwidth_mbps(400.0),
            )
            .event(
                "a",
                "b",
                1.0,
                LinkChange::Degrade { latency_factor: 3.0, bandwidth_factor: 0.5 },
            )
            .event(
                "a",
                "b",
                2.0,
                LinkChange::Degrade { latency_factor: 3.0, bandwidth_factor: 0.5 },
            )
            .event("a", "b", 3.0, LinkChange::Partition)
            .event(
                "a",
                "b",
                4.0,
                LinkChange::Degrade { latency_factor: 2.0, bandwidth_factor: 1.0 },
            )
            .build();
        topo.apply_event(0);
        topo.apply_event(1);
        let l = *topo.link(0, 1);
        assert!((l.latency.0 - 0.120).abs() < 1e-12, "got {}", l.latency.0);
        assert!((l.latency.1 - 0.150).abs() < 1e-12);
        assert!((l.jitter - 0.012).abs() < 1e-12);
        assert!((l.bandwidth - 0.5 * 400.0 * 1e6 / 8.0).abs() < 1e-3);
        // A later degrade re-expresses severity vs. pristine…
        topo.apply_event(2);
        topo.apply_event(3);
        let l = *topo.link(0, 1);
        assert!((l.latency.0 - 0.080).abs() < 1e-12);
        assert!((l.bandwidth - 400.0 * 1e6 / 8.0).abs() < 1e-3);
        // …and does not quietly heal a partition.
        assert!(l.partitioned, "degrade must not heal a partition");
    }

    #[test]
    fn directional_degrade_leaves_return_path_pristine() {
        let mut topo = Topology::builder()
            .region("a")
            .region("b")
            .link(
                "a",
                "b",
                LinkProfile::new(0.040, 0.050).with_bandwidth_mbps(400.0),
            )
            .node("a")
            .node("b")
            .event(
                "a",
                "b",
                1.0,
                LinkChange::DegradeDirectional {
                    latency_factor: 4.0,
                    bandwidth_factor: 0.25,
                },
            )
            .event("a", "b", 2.0, LinkChange::Heal)
            .build();
        topo.apply_event(0);
        let fwd = *topo.link(0, 1);
        let rev = *topo.link(1, 0);
        assert!((fwd.latency.0 - 0.160).abs() < 1e-12, "a->b degraded");
        assert!((fwd.bandwidth - 0.25 * 400.0 * 1e6 / 8.0).abs() < 1e-3);
        assert!((rev.latency.0 - 0.040).abs() < 1e-12, "b->a pristine");
        assert!((rev.bandwidth - 400.0 * 1e6 / 8.0).abs() < 1e-3);
        // Sampled delays reflect the asymmetry: the degraded direction can
        // never be as fast as the pristine one's upper bound.
        let mut rng = Rng::new(8);
        for _ in 0..100 {
            let fwd = topo.sample_delay(0, 1, 0, &mut rng).unwrap();
            let rev = topo.sample_delay(1, 0, 0, &mut rng).unwrap();
            assert!(fwd > rev, "degraded {fwd} !> pristine {rev}");
        }
        // Heal is symmetric: it restores BOTH directions.
        topo.apply_event(1);
        assert_eq!(*topo.link(0, 1), *topo.link(1, 0));
        assert!((topo.link(0, 1).latency.0 - 0.040).abs() < 1e-12);
    }

    #[test]
    fn intra_region_event_applies_once() {
        let mut topo = Topology::builder()
            .region("a")
            .default_intra(LinkProfile::new(0.010, 0.020))
            .event(
                "a",
                "a",
                1.0,
                LinkChange::Degrade { latency_factor: 3.0, bandwidth_factor: 0.5 },
            )
            .build();
        topo.apply_event(0);
        let l = topo.link(0, 0);
        assert!(
            (l.latency.0 - 0.030).abs() < 1e-12,
            "intra-region degrade applied twice: {}",
            l.latency.0
        );
        assert!((l.latency.1 - 0.060).abs() < 1e-12);
    }

    #[test]
    fn events_sorted_by_time() {
        let topo = Topology::builder()
            .region("a")
            .region("b")
            .event("a", "b", 30.0, LinkChange::Heal)
            .event("a", "b", 10.0, LinkChange::Partition)
            .build();
        let times: Vec<f64> = topo.events().iter().map(|e| e.at).collect();
        assert_eq!(times, vec![10.0, 30.0]);
    }

    #[test]
    fn expected_latency_matrix_symmetric_and_static() {
        let topo = two_region();
        let m = topo.expected_latency_matrix();
        assert_eq!(m.len(), 2);
        assert!((m[0][1] - m[1][0]).abs() < 1e-12);
        assert!(m[0][0] < m[0][1]);
        // Estimates come from the pristine profiles: a live partition must
        // not leak into the dispatch-scoring matrix.
        let mut t2 = Topology::builder()
            .region("west")
            .region("east")
            .default_intra(LinkProfile::new(0.001, 0.002))
            .link("west", "east", LinkProfile::new(0.050, 0.060))
            .event("west", "east", 1.0, LinkChange::Partition)
            .build();
        t2.apply_event(0);
        assert_eq!(t2.expected_latency_matrix()[0][1], m[0][1]);
    }

    #[test]
    fn region_of_defaults_to_zero() {
        let topo = Topology::single_region((0.0, 0.0));
        assert_eq!(topo.region_of(0), 0);
        assert_eq!(topo.region_of(99), 0);
        let t2 = two_region();
        assert_eq!(t2.region_of(0), 0);
        assert_eq!(t2.region_of(3), 1);
    }

    #[test]
    #[should_panic(expected = "latency lo")]
    fn inverted_latency_range_panics() {
        Topology::builder()
            .region("a")
            .default_intra(LinkProfile::new(0.08, 0.02))
            .build();
    }

    #[test]
    #[should_panic(expected = "unknown region")]
    fn unknown_region_in_link_panics() {
        let _ = Topology::builder().region("a").link(
            "a",
            "nowhere",
            LinkProfile::new(0.0, 0.0),
        );
    }

    #[test]
    #[should_panic(expected = "node assignments")]
    fn wrong_assignment_count_panics() {
        let topo = two_region(); // 4 node assignments
        topo.validate(7);
    }

    #[test]
    fn preset_builds_and_validates() {
        let topo = three_region_wan(3).build();
        topo.validate(9);
        assert_eq!(topo.num_regions(), 3);
        assert_eq!(topo.region_of(0), 0);
        assert_eq!(topo.region_of(4), 1);
        assert_eq!(topo.region_of(8), 2);
        let m = topo.expected_latency_matrix();
        // eu<->asia is the longest haul; intra the shortest.
        assert!(m[1][2] > m[0][1]);
        assert!(m[0][0] < m[0][1]);
    }
}
