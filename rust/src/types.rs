//! Core identifiers and request/response types shared by every layer.

use std::fmt;

/// Simulation / coordination time in seconds (f64 keeps Poisson/exponential
/// math simple; the TCP runner maps it onto `Instant`).
pub type Time = f64;

/// Credits are integer micro-units to keep ledger arithmetic exact.
pub type Credits = u64;

/// 1 credit = 1_000_000 micro-credits.
pub const CREDIT: Credits = 1_000_000;

/// Stable node identity (index into the world's node table; the anonymous
/// network identity is `crypto::NodeKey`'s public hash, carried separately).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NodeId(pub u32);

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "n{}", self.0)
    }
}

/// Globally-unique request id: (origin node, per-origin sequence number).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct RequestId {
    pub origin: NodeId,
    pub seq: u64,
}

impl fmt::Display for RequestId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}#{}", self.origin, self.seq)
    }
}

/// An inference request as it travels through the network.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: RequestId,
    /// Token count of the prompt.
    pub prompt_tokens: u32,
    /// Tokens the model will generate (drawn by the workload generator; in
    /// the real-backend path this is the requested max_new_tokens).
    pub output_tokens: u32,
    /// Wall/sim time the user submitted it at its origin node.
    pub submitted_at: Time,
    /// Latency threshold for SLO accounting (seconds from submission).
    pub slo_deadline: Time,
    /// True if this request was created by the duel mechanism (a challenger
    /// copy or judge evaluation) rather than by a user — excluded from
    /// user-facing SLO metrics, counted for overhead accounting (§7.1).
    pub synthetic: bool,
    /// Raw prompt tokens (real-backend path only; empty in pure simulation).
    pub payload: Vec<u32>,
    /// Streaming-session id this request belongs to (a turn of a multi-turn
    /// conversation). `0` means a standalone request — the pre-streaming
    /// behaviour. Nonzero ids make dispatch KV-affine (see
    /// `coordinator::dispatch`).
    pub session: u64,
    /// Time-to-first-token budget (seconds from submission). `INFINITY`
    /// means no TTFT SLO — standalone requests only carry the end-to-end
    /// `slo_deadline`.
    pub ttft_deadline: Time,
}

/// How a completed request was executed — used by metrics and the credit
/// system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecKind {
    /// Served on the origin node's own backend.
    Local,
    /// Served by a peer after PoS delegation.
    Delegated,
    /// One of the two executions of a duel request.
    Duel,
    /// A judge evaluation run.
    Judge,
}

/// A completed response travelling back to the origin.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub id: RequestId,
    pub executor: NodeId,
    /// Hidden quality draw of this response (simulation stand-in for the
    /// actual text quality; see DESIGN.md §2). Judges observe it noisily.
    pub quality: f64,
    /// When the executor finished it.
    pub finished_at: Time,
    /// When the executor's backend emitted the first output token (absolute
    /// sim time; `None` when the backend predates phase tracking).
    pub first_token_at: Option<Time>,
    /// Generated tokens (real-backend path only).
    pub tokens: Vec<u32>,
}

/// Per-request lifecycle record kept by the metrics layer.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub id: RequestId,
    pub origin: NodeId,
    pub executor: NodeId,
    pub kind: ExecKind,
    pub prompt_tokens: u32,
    pub output_tokens: u32,
    pub submitted_at: Time,
    pub completed_at: Time,
    pub slo_deadline: Time,
    pub synthetic: bool,
    /// Streaming-session id (0 = standalone).
    pub session: u64,
    /// TTFT budget carried from the request (`INFINITY` = no TTFT SLO).
    pub ttft_deadline: Time,
    /// Absolute time of the first output token, when the serving backend
    /// reported it.
    pub first_token_at: Option<Time>,
}

impl RequestRecord {
    pub fn latency(&self) -> Time {
        self.completed_at - self.submitted_at
    }

    pub fn slo_met(&self) -> bool {
        self.latency() <= self.slo_deadline
    }

    /// Time-to-first-token, when the backend reported a first-token stamp.
    pub fn ttft(&self) -> Option<Time> {
        self.first_token_at.map(|t| t - self.submitted_at)
    }

    /// TTFT SLO verdict: `None` when the request carries no TTFT budget,
    /// otherwise whether the first token landed inside it (a request with a
    /// budget but no stamp counts as a miss).
    pub fn ttft_met(&self) -> Option<bool> {
        if self.ttft_deadline.is_infinite() {
            return None;
        }
        Some(self.ttft().is_some_and(|t| t <= self.ttft_deadline))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_record_slo() {
        let rec = RequestRecord {
            id: RequestId { origin: NodeId(0), seq: 1 },
            origin: NodeId(0),
            executor: NodeId(1),
            kind: ExecKind::Delegated,
            prompt_tokens: 100,
            output_tokens: 200,
            submitted_at: 10.0,
            completed_at: 40.0,
            slo_deadline: 35.0,
            synthetic: false,
            session: 0,
            ttft_deadline: f64::INFINITY,
            first_token_at: None,
        };
        assert!((rec.latency() - 30.0).abs() < 1e-9);
        assert!(rec.slo_met());
        let late = RequestRecord { completed_at: 50.0, ..rec.clone() };
        assert!(!late.slo_met());
    }

    #[test]
    fn ttft_accounting() {
        let rec = RequestRecord {
            id: RequestId { origin: NodeId(0), seq: 1 },
            origin: NodeId(0),
            executor: NodeId(1),
            kind: ExecKind::Delegated,
            prompt_tokens: 100,
            output_tokens: 200,
            submitted_at: 10.0,
            completed_at: 40.0,
            slo_deadline: 35.0,
            synthetic: false,
            session: 7,
            ttft_deadline: 4.0,
            first_token_at: Some(13.0),
        };
        assert_eq!(rec.ttft_met(), Some(true));
        assert!((rec.ttft().unwrap() - 3.0).abs() < 1e-9);
        let slow = RequestRecord { first_token_at: Some(15.5), ..rec.clone() };
        assert_eq!(slow.ttft_met(), Some(false));
        // A budget with no stamp is a miss; no budget is exempt entirely.
        let unstamped = RequestRecord { first_token_at: None, ..rec.clone() };
        assert_eq!(unstamped.ttft_met(), Some(false));
        let standalone =
            RequestRecord { ttft_deadline: f64::INFINITY, session: 0, ..rec };
        assert_eq!(standalone.ttft_met(), None);
    }

    #[test]
    fn display_forms() {
        assert_eq!(NodeId(3).to_string(), "n3");
        let id = RequestId { origin: NodeId(2), seq: 17 };
        assert_eq!(id.to_string(), "n2#17");
    }
}
