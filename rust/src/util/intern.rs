//! String interning for hot-path identifiers.
//!
//! The simulator's hot paths carry node and region identities as dense
//! `u32`s (`types::NodeId`, region indices); the human-readable names
//! exist only at the two boundaries — config parsing (strings in) and
//! export/reporting (strings out). [`Interner`] is the canonical table
//! tying the two together: `intern` assigns each distinct string the next
//! dense id (idempotently — re-interning returns the same id), `resolve`
//! maps an id back to its string and **panics loudly on an unknown id**
//! rather than fabricating a default, because an unknown id at a reporting
//! boundary means a corrupted identifier escaped the sim core.
//!
//! Determinism: ids are assigned in first-intern order, so identical
//! configs processed in identical order produce identical id assignments —
//! the interner introduces no hashing and no per-process state. (Backing
//! storage is a `Vec` + `BTreeMap`; iteration order is id order.)

use std::collections::BTreeMap;

/// Dense `u32` ids for a set of distinct strings. See module docs.
#[derive(Debug, Clone, Default)]
pub struct Interner {
    names: Vec<String>,
    index: BTreeMap<String, u32>,
}

impl Interner {
    pub fn new() -> Interner {
        Interner::default()
    }

    /// Intern `name`, returning its dense id. Idempotent: the same string
    /// always maps to the id assigned at its first interning.
    pub fn intern(&mut self, name: &str) -> u32 {
        if let Some(&id) = self.index.get(name) {
            return id;
        }
        let id = u32::try_from(self.names.len())
            .expect("interner: more than u32::MAX distinct labels");
        self.names.push(name.to_string());
        self.index.insert(name.to_string(), id);
        id
    }

    /// The id of an already-interned string, or `None`.
    pub fn lookup(&self, name: &str) -> Option<u32> {
        self.index.get(name).copied()
    }

    /// Resolve an id back to its string. Panics on an unknown id — a
    /// silent default here would let a corrupted identifier masquerade as
    /// a real one all the way into reports.
    pub fn resolve(&self, id: u32) -> &str {
        self.try_resolve(id).unwrap_or_else(|| {
            panic!(
                "interner: unknown id {id} (only {} labels interned)",
                self.names.len()
            )
        })
    }

    /// Non-panicking resolve, for callers that can represent absence.
    pub fn try_resolve(&self, id: u32) -> Option<&str> {
        self.names.get(id as usize).map(String::as_str)
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// All interned strings in id order (id = position).
    pub fn iter(&self) -> impl Iterator<Item = (u32, &str)> {
        self.names
            .iter()
            .enumerate()
            .map(|(i, s)| (i as u32, s.as_str()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_is_idempotent_and_dense() {
        let mut it = Interner::new();
        let us = it.intern("us");
        let eu = it.intern("eu");
        assert_eq!(us, 0);
        assert_eq!(eu, 1);
        assert_eq!(it.intern("us"), us, "re-intern must return the same id");
        assert_eq!(it.intern("eu"), eu);
        assert_eq!(it.len(), 2);
    }

    #[test]
    fn ids_stable_across_identical_build_sequences() {
        // Two interners fed the same strings in the same order assign the
        // same ids — the property World construction determinism rests on.
        let feed = ["asia", "us", "eu", "us", "asia"];
        let mut a = Interner::new();
        let mut b = Interner::new();
        let ids_a: Vec<u32> = feed.iter().map(|s| a.intern(s)).collect();
        let ids_b: Vec<u32> = feed.iter().map(|s| b.intern(s)).collect();
        assert_eq!(ids_a, ids_b);
        assert_eq!(ids_a, vec![0, 1, 2, 1, 0]);
    }

    #[test]
    fn resolve_round_trips() {
        let mut it = Interner::new();
        let id = it.intern("eu-west");
        assert_eq!(it.resolve(id), "eu-west");
        assert_eq!(it.lookup("eu-west"), Some(id));
        assert_eq!(it.lookup("nowhere"), None);
        assert_eq!(it.try_resolve(id), Some("eu-west"));
        assert_eq!(it.try_resolve(id + 1), None);
    }

    #[test]
    #[should_panic(expected = "unknown id 7")]
    fn unknown_id_resolution_is_a_loud_error() {
        let mut it = Interner::new();
        it.intern("only");
        let _ = it.resolve(7);
    }

    #[test]
    fn iter_is_id_ordered() {
        let mut it = Interner::new();
        for name in ["c", "a", "b"] {
            it.intern(name);
        }
        let all: Vec<(u32, &str)> = it.iter().collect();
        assert_eq!(all, vec![(0, "c"), (1, "a"), (2, "b")]);
    }
}
