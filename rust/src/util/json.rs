//! Minimal JSON: value model, recursive-descent parser, serializer.
//!
//! Stands in for `serde_json` (unavailable offline — DESIGN.md §8). Used for
//! the artifact manifest, experiment configs, and the TCP wire format. Not a
//! general-purpose library: it supports exactly the JSON we produce/consume —
//! UTF-8 text, `\uXXXX` escapes (BMP only), f64 numbers.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use BTreeMap so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // ---- constructors -----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // ---- accessors --------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 {
                Some(f as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` lookup; returns Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Path lookup: `j.at(&["model", "vocab"])`.
    pub fn at(&self, path: &[&str]) -> &Json {
        path.iter().fold(self, |j, k| j.get(k))
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ---- parsing ----------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- serialization ----------------------------------------------------

    // Serialization happens through `Display` (so `.to_string()` works via
    // the blanket `ToString`); see the impl at the bottom of the file.

    /// Rough serialized size (serializer pre-allocation).
    fn size_hint(&self) -> usize {
        match self {
            Json::Null | Json::Bool(_) => 5,
            Json::Num(_) => 8,
            Json::Str(s) => s.len() + 2,
            Json::Arr(a) => {
                2 + a.iter().map(|v| v.size_hint() + 1).sum::<usize>()
            }
            Json::Obj(o) => {
                2 + o
                    .iter()
                    .map(|(k, v)| k.len() + 4 + v.size_hint())
                    .sum::<usize>()
            }
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    write_i64(*n as i64, out); // fast path, no fmt machinery
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut out = String::with_capacity(self.size_hint());
        self.write(&mut out);
        f.write_str(&out)
    }
}

/// Integer-to-decimal without going through `format!` (the serializer's
/// hot path — token-id arrays are almost entirely small integers).
fn write_i64(mut v: i64, out: &mut String) {
    if v == 0 {
        out.push('0');
        return;
    }
    if v < 0 {
        out.push('-');
        v = -v;
    }
    let mut buf = [0u8; 20];
    let mut i = buf.len();
    while v > 0 {
        i -= 1;
        buf[i] = b'0' + (v % 10) as u8;
        v /= 10;
    }
    out.push_str(std::str::from_utf8(&buf[i..]).expect("ascii digits"));
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let start = self.pos;
                    let rest = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        let neg = self.peek() == Some(b'-');
        if neg {
            self.pos += 1;
        }
        // Integer fast path (the wire format is mostly token ids): accumulate
        // digits directly; fall back to str::parse for fractions/exponents
        // and anything that might lose precision.
        let mut int_acc: u64 = 0;
        let mut digits = 0usize;
        while let Some(c) = self.peek() {
            if !c.is_ascii_digit() {
                break;
            }
            int_acc = int_acc.wrapping_mul(10).wrapping_add((c - b'0') as u64);
            digits += 1;
            self.pos += 1;
        }
        let is_plain_int =
            digits > 0 && digits <= 15 && !matches!(self.peek(), Some(b'.' | b'e' | b'E'));
        if is_plain_int {
            let v = int_acc as f64;
            return Ok(Json::Num(if neg { -v } else { v }));
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-3", "2.5", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(
            r#"{"a": [1, 2, {"b": null}], "c": "x\ny", "d": -1.5e3}"#,
        )
        .unwrap();
        assert_eq!(v.at(&["a"]).as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").as_str(), Some("x\ny"));
        assert_eq!(v.get("d").as_f64(), Some(-1500.0));
        assert!(v.at(&["a"]).as_arr().unwrap()[2].get("b").is_null());
    }

    #[test]
    fn roundtrip_deep() {
        let v = Json::obj(vec![
            ("name", Json::str("wwwserve")),
            (
                "nested",
                Json::obj(vec![
                    ("arr", Json::Arr(vec![Json::num(1), Json::Bool(true)])),
                    ("s", Json::str("a\"b\\c\n")),
                ]),
            ),
        ]);
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é中""#).unwrap();
        assert_eq!(v.as_str(), Some("é中"));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo 世界"));
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::num(5).to_string(), "5");
        assert_eq!(Json::num(-2).to_string(), "-2");
        assert_eq!(Json::num(2.5).to_string(), "2.5");
    }

    #[test]
    fn accessor_defaults() {
        let v = Json::parse(r#"{"a": 1}"#).unwrap();
        assert!(v.get("missing").is_null());
        assert!(v.at(&["a", "b", "c"]).is_null());
        assert_eq!(v.get("a").as_u64(), Some(1));
        assert_eq!(Json::Num(1.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
    }

    #[test]
    fn whitespace_tolerance() {
        let v = Json::parse(" {\n\t\"a\" :\r [ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 2);
    }
}
