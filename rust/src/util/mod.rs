//! Small in-repo substrates standing in for unavailable third-party crates
//! (offline image — see DESIGN.md §8): deterministic RNG + samplers, JSON,
//! hex encoding, and string interning for hot-path identifiers.

pub mod intern;
pub mod json;
pub mod rng;

/// Lower-case hex encoding (stands in for the `hex` crate).
pub fn hex_encode(bytes: &[u8]) -> String {
    const TABLE: &[u8; 16] = b"0123456789abcdef";
    let mut out = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        out.push(TABLE[(b >> 4) as usize] as char);
        out.push(TABLE[(b & 0xf) as usize] as char);
    }
    out
}

/// Hex decode; returns None on odd length or non-hex characters.
pub fn hex_decode(s: &str) -> Option<Vec<u8>> {
    if s.len() % 2 != 0 {
        return None;
    }
    let nib = |c: u8| -> Option<u8> {
        match c {
            b'0'..=b'9' => Some(c - b'0'),
            b'a'..=b'f' => Some(c - b'a' + 10),
            b'A'..=b'F' => Some(c - b'A' + 10),
            _ => None,
        }
    };
    let b = s.as_bytes();
    (0..s.len() / 2)
        .map(|i| Some(nib(b[2 * i])? << 4 | nib(b[2 * i + 1])?))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hex_roundtrip() {
        let data = [0u8, 1, 15, 16, 127, 128, 255];
        let s = hex_encode(&data);
        assert_eq!(s, "00010f107f80ff");
        assert_eq!(hex_decode(&s).unwrap(), data);
        assert_eq!(hex_decode("00010F107F80FF").unwrap(), data);
    }

    #[test]
    fn hex_decode_invalid() {
        assert!(hex_decode("0").is_none());
        assert!(hex_decode("zz").is_none());
    }
}
