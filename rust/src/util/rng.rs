//! Deterministic PRNG + distribution sampling.
//!
//! The cargo registry in this image has no `rand`/`rand_distr`, so this module
//! implements the pieces WWW.Serve needs from scratch: a xoshiro256++ engine
//! seeded via splitmix64, and the samplers used by the workload generator and
//! the PoS scheduler (uniform, exponential, Poisson, normal, log-normal,
//! categorical). Everything is deterministic in the seed — the whole simulator
//! replays bit-identically, which the integration tests rely on.

/// xoshiro256++ — fast, high-quality, 256-bit state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; any u64 is fine (splitmix64 whitens it).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-node RNGs from a world seed).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift; bias is negligible for our n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with the given rate (mean = 1/rate).
    pub fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        let u = 1.0 - self.f64(); // avoid ln(0)
        -u.ln() / rate
    }

    /// Standard normal via Box–Muller (single value; we don't cache pairs to
    /// keep replay behaviour obvious).
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal parameterized by the *target* mean and sigma of the
    /// underlying normal (a convenient form for length distributions).
    pub fn lognormal_mean(&mut self, target_mean: f64, sigma: f64) -> f64 {
        // If X = exp(N(mu, sigma)), E[X] = exp(mu + sigma^2/2).
        let mu = target_mean.ln() - sigma * sigma / 2.0;
        (mu + sigma * self.normal()).exp()
    }

    /// Poisson(lambda) — inversion for small lambda, normal approx for large.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        debug_assert!(lambda >= 0.0);
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let v = self.normal_ms(lambda, lambda.sqrt()).round();
            if v < 0.0 {
                0
            } else {
                v as u64
            }
        }
    }

    /// Sample an index proportionally to `weights` (linear scan).
    /// Returns None if all weights are zero/negative.
    pub fn weighted(&mut self, weights: &[f64]) -> Option<usize> {
        let total: f64 = weights.iter().filter(|w| **w > 0.0).sum();
        if total <= 0.0 {
            return None;
        }
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if *w > 0.0 {
                x -= w;
                if x <= 0.0 {
                    return Some(i);
                }
            }
        }
        // Floating point slack: return the last positive entry.
        weights.iter().rposition(|w| *w > 0.0)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// k distinct indices from [0, n), weighted-without-replacement if
    /// weights given (used for judge selection).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k.min(n));
        idx
    }
}

/// Alias-method sampler: O(n) build, O(1) sample. Used on the PoS hot path
/// when the stake table is large (see benches/micro.rs for the crossover).
#[derive(Debug, Clone)]
pub struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<usize>,
}

impl AliasTable {
    /// Build from non-negative weights. Returns None if no positive weight.
    pub fn new(weights: &[f64]) -> Option<AliasTable> {
        let n = weights.len();
        let total: f64 = weights.iter().filter(|w| **w > 0.0).sum();
        if n == 0 || total <= 0.0 {
            return None;
        }
        let scale = n as f64 / total;
        let mut prob: Vec<f64> = weights.iter().map(|w| w.max(0.0) * scale).collect();
        let mut alias = vec![0usize; n];
        let mut small: Vec<usize> = Vec::with_capacity(n);
        let mut large: Vec<usize> = Vec::with_capacity(n);
        for (i, p) in prob.iter().enumerate() {
            if *p < 1.0 {
                small.push(i);
            } else {
                large.push(i);
            }
        }
        while let (Some(s), Some(l)) = (small.pop(), large.pop()) {
            alias[s] = l;
            prob[l] = (prob[l] + prob[s]) - 1.0;
            if prob[l] < 1.0 {
                small.push(l);
            } else {
                large.push(l);
            }
        }
        // Leftovers are 1.0 up to rounding.
        Some(AliasTable { prob, alias })
    }

    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let i = rng.below(self.prob.len());
        if rng.f64() < self.prob[i] {
            i
        } else {
            self.alias[i]
        }
    }

    pub fn len(&self) -> usize {
        self.prob.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_replay() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for n in [1usize, 2, 7, 100] {
            for _ in 0..1000 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exp(0.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn poisson_small_mean() {
        let mut r = Rng::new(17);
        let n = 100_000;
        let mean: f64 =
            (0..n).map(|_| r.poisson(3.5) as f64).sum::<f64>() / n as f64;
        assert!((mean - 3.5).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn poisson_large_mean_normal_path() {
        let mut r = Rng::new(19);
        let n = 50_000;
        let mean: f64 =
            (0..n).map(|_| r.poisson(120.0) as f64).sum::<f64>() / n as f64;
        assert!((mean - 120.0).abs() < 0.5, "mean={mean}");
    }

    #[test]
    fn lognormal_target_mean() {
        let mut r = Rng::new(23);
        let n = 300_000;
        let mean: f64 = (0..n)
            .map(|_| r.lognormal_mean(100.0, 0.5))
            .sum::<f64>()
            / n as f64;
        assert!((mean - 100.0).abs() < 2.0, "mean={mean}");
    }

    #[test]
    fn weighted_proportions() {
        let mut r = Rng::new(29);
        let w = [1.0, 2.0, 3.0, 0.0];
        let mut counts = [0usize; 4];
        let n = 120_000;
        for _ in 0..n {
            counts[r.weighted(&w).unwrap()] += 1;
        }
        assert_eq!(counts[3], 0);
        let c0 = counts[0] as f64 / n as f64;
        let c2 = counts[2] as f64 / n as f64;
        assert!((c0 - 1.0 / 6.0).abs() < 0.01);
        assert!((c2 - 0.5).abs() < 0.01);
    }

    #[test]
    fn weighted_all_zero_is_none() {
        let mut r = Rng::new(31);
        assert_eq!(r.weighted(&[0.0, 0.0]), None);
        assert_eq!(r.weighted(&[]), None);
    }

    #[test]
    fn alias_matches_weighted() {
        let mut r = Rng::new(37);
        let w = [0.5, 4.5, 2.0, 0.0, 3.0];
        let table = AliasTable::new(&w).unwrap();
        let mut counts = [0usize; 5];
        let n = 200_000;
        for _ in 0..n {
            counts[table.sample(&mut r)] += 1;
        }
        assert_eq!(counts[3], 0);
        let total: f64 = w.iter().sum();
        for (i, wi) in w.iter().enumerate() {
            let expected = wi / total;
            let got = counts[i] as f64 / n as f64;
            assert!(
                (got - expected).abs() < 0.01,
                "i={i} got={got} want={expected}"
            );
        }
    }

    #[test]
    fn alias_empty_and_zero() {
        assert!(AliasTable::new(&[]).is_none());
        assert!(AliasTable::new(&[0.0, 0.0]).is_none());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(41);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_unique() {
        let mut r = Rng::new(43);
        for _ in 0..100 {
            let s = r.sample_distinct(10, 4);
            assert_eq!(s.len(), 4);
            let mut d = s.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), 4);
        }
        assert_eq!(r.sample_distinct(3, 10).len(), 3);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
