//! Workload generation: piecewise-Poisson arrivals (Table 3) and
//! reasoning-style request length distributions (OpenR1-Math substitution,
//! DESIGN.md §2).

pub mod settings;

pub use settings::{NodeSpec, Setting, SettingId};

use crate::types::{NodeId, Request, RequestId, Time};
use crate::util::rng::Rng;

/// One interval of a node's request schedule: Poisson arrivals with expected
/// inter-arrival time `inter_arrival` (Table 3's 1/λ columns).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Phase {
    pub from: Time,
    pub to: Time,
    /// Expected seconds between arrivals (1/λ).
    pub inter_arrival: f64,
}

impl Phase {
    pub fn new(from: Time, to: Time, inter_arrival: f64) -> Phase {
        Phase { from, to, inter_arrival }
    }

    /// The same interval translated by `dt` (regional schedule offsets).
    pub fn shifted(self, dt: Time) -> Phase {
        Phase { from: self.from + dt, to: self.to + dt, ..self }
    }
}

/// A follow-the-sun diurnal schedule: alternating peak / off-peak windows of
/// `period / 2` seconds each, with the first peak starting at `offset`
/// (cycle-shifted, so negative-phase windows wrap in), clipped to
/// `[0, horizon]`. Give each region an offset of `period / num_regions` to
/// stagger the peaks around the globe — the paper's geo-distributed load
/// scenario where one continent's rush hour is another's night.
pub fn diurnal_phases(
    horizon: Time,
    period: Time,
    peak_inter_arrival: f64,
    off_inter_arrival: f64,
    offset: Time,
) -> Vec<Phase> {
    assert!(period > 0.0 && horizon >= 0.0, "diurnal: period must be > 0");
    let half = period / 2.0;
    let mut out = Vec::new();
    // Walk half-period windows from the boundary at or before t = 0.
    let mut k = ((0.0 - offset) / half).floor() as i64;
    loop {
        let start = offset + k as f64 * half;
        if start >= horizon {
            break;
        }
        let end = start + half;
        if end > 0.0 {
            let ia = if k.rem_euclid(2) == 0 {
                peak_inter_arrival
            } else {
                off_inter_arrival
            };
            out.push(Phase::new(start.max(0.0), end.min(horizon), ia));
        }
        k += 1;
    }
    out
}

/// Prompt/output token length distributions.
///
/// Calibrated to reasoning workloads (OpenR1-Math-220k): medium prompts,
/// long chain-of-thought outputs capped at the paper's 8192 max-token limit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LengthDist {
    pub prompt_mean: f64,
    pub prompt_sigma: f64,
    pub output_mean: f64,
    pub output_sigma: f64,
    pub max_tokens: u32,
}

impl Default for LengthDist {
    fn default() -> Self {
        LengthDist {
            prompt_mean: 300.0,
            prompt_sigma: 0.6,
            // Reasoning-length outputs (OpenR1-Math chains-of-thought at
            // temperature 0 with the paper's 8192-token cap): calibrated so
            // Table-3 loads produce the paper's ~200 s latency regime.
            output_mean: 4500.0,
            output_sigma: 0.6,
            max_tokens: 8192,
        }
    }
}

impl LengthDist {
    pub fn sample_prompt(&self, rng: &mut Rng) -> u32 {
        (rng.lognormal_mean(self.prompt_mean, self.prompt_sigma) as u32)
            .clamp(8, self.max_tokens / 2)
    }

    pub fn sample_output(&self, rng: &mut Rng) -> u32 {
        (rng.lognormal_mean(self.output_mean, self.output_sigma) as u32)
            .clamp(16, self.max_tokens)
    }
}

/// SLO deadline model: a request's deadline scales with its expected service
/// demand on a reference server (so SLO attainment compares scheduling
/// quality, not workload luck). `slo_scale` is the figure-4 style tightness
/// knob.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloModel {
    /// Reference decode speed (tok/s) used to convert tokens to seconds.
    pub ref_decode_tok_s: f64,
    pub ref_prefill_tok_s: f64,
    /// Multiplier on the reference service time.
    pub slo_scale: f64,
    /// Floor on any deadline (seconds).
    pub min_deadline: f64,
}

impl Default for SloModel {
    fn default() -> Self {
        SloModel {
            ref_decode_tok_s: 30.0,
            ref_prefill_tok_s: 4000.0,
            slo_scale: 1.0,
            min_deadline: 30.0,
        }
    }
}

impl SloModel {
    pub fn deadline(&self, prompt_tokens: u32, output_tokens: u32) -> Time {
        let svc = prompt_tokens as f64 / self.ref_prefill_tok_s
            + output_tokens as f64 / self.ref_decode_tok_s;
        (svc * self.slo_scale).max(self.min_deadline)
    }

    /// Expected service seconds on the reference server (used to place the
    /// next turn of a session after the previous one would finish).
    pub fn ref_service(&self, prompt_tokens: u32, output_tokens: u32) -> Time {
        prompt_tokens as f64 / self.ref_prefill_tok_s
            + output_tokens as f64 / self.ref_decode_tok_s
    }
}

/// Multi-turn streaming-session shape: how many turns a conversation runs,
/// how long the user thinks between them, and how tight the per-turn TTFT
/// budget is. Turn-level prompt/output lengths still come from the
/// generator's [`LengthDist`]; the end-to-end deadline still comes from its
/// [`SloModel`] — sessions only *add* the TTFT dimension and the arrival
/// correlation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SessionProfile {
    /// Mean turns per session (Poisson-shifted: `1 + Poisson(mean - 1)`).
    pub turns_mean: f64,
    /// Hard cap on turns per session.
    pub max_turns: u32,
    /// Mean think time between a turn's expected completion and the next
    /// turn's submission (exponential).
    pub think_mean: f64,
    /// TTFT budget multiplier over the reference prefill time
    /// (`prompt / ref_prefill_tok_s * slo_scale * ttft_scale`).
    pub ttft_scale: f64,
    /// Floor on the TTFT budget (seconds) — reference prefill is fast, so
    /// this floor is what queueing, WAN hops and KV transfers must fit in.
    pub ttft_floor: f64,
}

impl Default for SessionProfile {
    fn default() -> Self {
        SessionProfile {
            turns_mean: 3.0,
            max_turns: 12,
            think_mean: 20.0,
            ttft_scale: 3.0,
            ttft_floor: 2.0,
        }
    }
}

impl SessionProfile {
    pub fn check(&self) -> Result<(), String> {
        if !self.turns_mean.is_finite() || self.turns_mean < 1.0 {
            return Err(format!(
                "turns_mean must be >= 1, got {}",
                self.turns_mean
            ));
        }
        if self.max_turns == 0 {
            return Err("max_turns must be >= 1".into());
        }
        if !self.think_mean.is_finite() || self.think_mean < 0.0 {
            return Err(format!(
                "think_mean must be >= 0, got {}",
                self.think_mean
            ));
        }
        if !self.ttft_scale.is_finite() || self.ttft_scale <= 0.0 {
            return Err(format!(
                "ttft_scale must be > 0, got {}",
                self.ttft_scale
            ));
        }
        if !self.ttft_floor.is_finite() || self.ttft_floor <= 0.0 {
            return Err(format!(
                "ttft_floor must be > 0, got {}",
                self.ttft_floor
            ));
        }
        Ok(())
    }
}

/// Generates one node's request stream.
#[derive(Debug, Clone)]
pub struct Generator {
    pub origin: NodeId,
    pub phases: Vec<Phase>,
    pub lengths: LengthDist,
    pub slo: SloModel,
    /// When set, [`Generator::session_trace`] turns each Poisson arrival
    /// into a multi-turn session instead of a standalone request.
    pub sessions: Option<SessionProfile>,
    next_seq: u64,
    next_session: u64,
}

impl Generator {
    pub fn new(origin: NodeId, phases: Vec<Phase>) -> Generator {
        Generator {
            origin,
            phases,
            lengths: LengthDist::default(),
            slo: SloModel::default(),
            sessions: None,
            next_seq: 0,
            next_session: 0,
        }
    }

    pub fn with_lengths(mut self, lengths: LengthDist) -> Self {
        self.lengths = lengths;
        self
    }

    pub fn with_slo(mut self, slo: SloModel) -> Self {
        self.slo = slo;
        self
    }

    pub fn with_sessions(mut self, sessions: SessionProfile) -> Self {
        self.sessions = Some(sessions);
        self
    }

    /// Translate the whole schedule by `dt` seconds (per-region offsets for
    /// geo-distributed workloads; arrivals before t=0 simply never fire).
    pub fn with_offset(mut self, dt: Time) -> Self {
        for ph in &mut self.phases {
            *ph = ph.shifted(dt);
        }
        self
    }

    /// Draw all arrival times over the schedule (exponential gaps per
    /// phase).
    pub fn arrivals(&self, rng: &mut Rng) -> Vec<Time> {
        let mut out = Vec::new();
        for ph in &self.phases {
            if ph.inter_arrival <= 0.0 {
                continue;
            }
            let mut t = ph.from + rng.exp(1.0 / ph.inter_arrival);
            while t < ph.to {
                // Negative times can arise from offset schedules whose
                // window straddles t=0; those arrivals never happen.
                if t >= 0.0 {
                    out.push(t);
                }
                t += rng.exp(1.0 / ph.inter_arrival);
            }
        }
        out.sort_by(|a, b| a.partial_cmp(b).unwrap());
        out
    }

    /// Materialize a full request at an arrival time.
    pub fn make_request(&mut self, at: Time, rng: &mut Rng) -> Request {
        let prompt = self.lengths.sample_prompt(rng);
        let output = self.lengths.sample_output(rng);
        let seq = self.next_seq;
        self.next_seq += 1;
        Request {
            id: RequestId { origin: self.origin, seq },
            prompt_tokens: prompt,
            output_tokens: output,
            submitted_at: at,
            slo_deadline: self.slo.deadline(prompt, output),
            synthetic: false,
            payload: vec![],
            session: 0,
            ttft_deadline: f64::INFINITY,
        }
    }

    /// Generate the whole trace (arrival-sorted).
    pub fn trace(&mut self, rng: &mut Rng) -> Vec<Request> {
        let times = self.arrivals(rng);
        times
            .into_iter()
            .map(|t| self.make_request(t, rng))
            .collect()
    }

    /// Session form of [`Generator::trace`]: each Poisson arrival seeds a
    /// multi-turn session. Turn k+1 is submitted after turn k's expected
    /// reference service time plus an exponential think gap; every turn
    /// carries the session id and a TTFT deadline. Falls back to the plain
    /// trace (draw for draw) when no [`SessionProfile`] is configured.
    ///
    /// All randomness comes from the caller's `rng` stream — the generator
    /// never constructs one (determinism contract, docs/determinism.md).
    pub fn session_trace(&mut self, rng: &mut Rng) -> Vec<Request> {
        let Some(sp) = self.sessions else {
            return self.trace(rng);
        };
        let starts = self.arrivals(rng);
        let mut out = Vec::new();
        for start in starts {
            self.next_session += 1;
            // Nonzero, globally unique: origin in the high bits.
            let session =
                ((self.origin.0 as u64 + 1) << 32) | self.next_session;
            let turns = (1 + rng.poisson((sp.turns_mean - 1.0).max(0.0)))
                .min(sp.max_turns as u64);
            let mut at = start;
            for _turn in 0..turns {
                let mut req = self.make_request(at, rng);
                req.session = session;
                req.ttft_deadline = (req.prompt_tokens as f64
                    / self.slo.ref_prefill_tok_s
                    * self.slo.slo_scale
                    * sp.ttft_scale)
                    .max(sp.ttft_floor);
                let svc =
                    self.slo.ref_service(req.prompt_tokens, req.output_tokens);
                let think = if sp.think_mean > 0.0 {
                    rng.exp(1.0 / sp.think_mean)
                } else {
                    0.0
                };
                out.push(req);
                at += svc + think;
            }
        }
        // Interleave sessions into one arrival-ordered stream; ties break
        // on the (already unique) sequence number for determinism.
        out.sort_by(|a, b| {
            a.submitted_at
                .partial_cmp(&b.submitted_at)
                .unwrap()
                .then(a.id.seq.cmp(&b.id.seq))
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrival_rate_matches_phase() {
        let g = Generator::new(
            NodeId(0),
            vec![Phase::new(0.0, 10_000.0, 5.0)],
        );
        let mut rng = Rng::new(1);
        let arr = g.arrivals(&mut rng);
        let rate = arr.len() as f64 / 10_000.0;
        assert!((rate - 0.2).abs() < 0.01, "rate={rate}");
        for w in arr.windows(2) {
            assert!(w[0] <= w[1]);
        }
        assert!(arr.iter().all(|t| (0.0..10_000.0).contains(t)));
    }

    #[test]
    fn piecewise_phases_change_rate() {
        let g = Generator::new(
            NodeId(0),
            vec![
                Phase::new(0.0, 5_000.0, 2.0),
                Phase::new(5_000.0, 10_000.0, 20.0),
            ],
        );
        let mut rng = Rng::new(2);
        let arr = g.arrivals(&mut rng);
        let early = arr.iter().filter(|t| **t < 5_000.0).count() as f64;
        let late = arr.len() as f64 - early;
        assert!((early / 5_000.0 - 0.5).abs() < 0.02);
        assert!((late / 5_000.0 - 0.05).abs() < 0.01);
    }

    #[test]
    fn diurnal_phases_tile_the_horizon() {
        let phases = diurnal_phases(750.0, 300.0, 2.0, 20.0, 0.0);
        // Contiguous cover of [0, 750].
        assert_eq!(phases[0].from, 0.0);
        assert_eq!(phases.last().unwrap().to, 750.0);
        for w in phases.windows(2) {
            assert!((w[0].to - w[1].from).abs() < 1e-9);
        }
        // Alternating peak/off rates starting with the peak.
        assert_eq!(phases[0].inter_arrival, 2.0);
        assert_eq!(phases[1].inter_arrival, 20.0);
        assert_eq!(phases[2].inter_arrival, 2.0);
    }

    #[test]
    fn diurnal_offset_rotates_peaks() {
        // Offset of a third of the period: the first window is the tail of
        // the previous cycle's off-peak, clipped at t=0.
        let phases = diurnal_phases(600.0, 300.0, 2.0, 20.0, 100.0);
        assert_eq!(phases[0].from, 0.0);
        assert!((phases[0].to - 100.0).abs() < 1e-9);
        assert_eq!(phases[0].inter_arrival, 20.0);
        assert_eq!(phases[1].inter_arrival, 2.0);
        assert!((phases[1].from - 100.0).abs() < 1e-9);
        assert_eq!(phases.last().unwrap().to, 600.0);
        // Total peak seconds match the unshifted schedule (mass conserved
        // up to horizon clipping).
        let peak_secs: f64 = phases
            .iter()
            .filter(|p| p.inter_arrival == 2.0)
            .map(|p| p.to - p.from)
            .sum();
        assert!((peak_secs - 300.0).abs() < 1e-9);
    }

    #[test]
    fn generator_offset_shifts_arrivals() {
        let base = Generator::new(NodeId(0), vec![Phase::new(0.0, 100.0, 5.0)]);
        let shifted = base.clone().with_offset(50.0);
        assert_eq!(shifted.phases[0].from, 50.0);
        assert_eq!(shifted.phases[0].to, 150.0);
        let mut rng = Rng::new(8);
        let arr = shifted.arrivals(&mut rng);
        assert!(arr.iter().all(|t| (50.0..150.0).contains(t)));
        // A negative offset clips pre-zero arrivals instead of emitting
        // negative timestamps.
        let early = base.clone().with_offset(-90.0);
        let mut rng = Rng::new(8);
        let arr = early.arrivals(&mut rng);
        assert!(arr.iter().all(|t| (0.0..10.0).contains(t)));
    }

    #[test]
    fn lengths_within_bounds() {
        let d = LengthDist::default();
        let mut rng = Rng::new(3);
        for _ in 0..10_000 {
            let p = d.sample_prompt(&mut rng);
            let o = d.sample_output(&mut rng);
            assert!((8..=4096).contains(&p));
            assert!((16..=8192).contains(&o));
        }
    }

    #[test]
    fn slo_deadline_scales_with_work() {
        let slo = SloModel::default();
        let short = slo.deadline(100, 100);
        let long = slo.deadline(1000, 8000);
        assert!(long > short);
        assert!(short >= slo.min_deadline);
        // 8000 tokens at 30 tok/s ref ≈ 266 s + prefill, at scale 1.0.
        assert!((long - (1000.0 / 4000.0 + 8000.0 / 30.0)).abs() < 1e-9);
    }

    #[test]
    fn request_ids_unique_and_sequential() {
        let mut g = Generator::new(NodeId(3), vec![Phase::new(0.0, 100.0, 1.0)]);
        let mut rng = Rng::new(4);
        let trace = g.trace(&mut rng);
        for (i, r) in trace.iter().enumerate() {
            assert_eq!(r.id.seq, i as u64);
            assert_eq!(r.id.origin, NodeId(3));
            assert!(!r.synthetic);
        }
    }

    #[test]
    fn session_trace_without_profile_matches_plain_trace() {
        let mk = || Generator::new(NodeId(0), vec![Phase::new(0.0, 500.0, 2.0)]);
        let plain = {
            let mut g = mk();
            let mut rng = Rng::new(11);
            g.trace(&mut rng)
        };
        let sessionless = {
            let mut g = mk();
            let mut rng = Rng::new(11);
            g.session_trace(&mut rng)
        };
        assert_eq!(plain, sessionless, "no profile => identical draw stream");
    }

    #[test]
    fn session_trace_shape() {
        let mut g = Generator::new(NodeId(2), vec![Phase::new(0.0, 500.0, 10.0)])
            .with_sessions(SessionProfile::default());
        let mut rng = Rng::new(5);
        let trace = g.session_trace(&mut rng);
        assert!(!trace.is_empty());
        // Arrival-sorted, unique seqs.
        for w in trace.windows(2) {
            assert!(w[0].submitted_at <= w[1].submitted_at);
        }
        let mut sessions = std::collections::BTreeMap::new();
        for r in &trace {
            assert_ne!(r.session, 0, "session turns carry a nonzero id");
            assert!(r.ttft_deadline.is_finite());
            assert!(r.ttft_deadline >= SessionProfile::default().ttft_floor);
            assert!(r.slo_deadline >= r.ttft_deadline || r.slo_deadline >= 30.0);
            sessions.entry(r.session).or_insert_with(Vec::new).push(r);
        }
        let max_turns = SessionProfile::default().max_turns as usize;
        let mut multi = 0;
        for turns in sessions.values() {
            assert!((1..=max_turns).contains(&turns.len()));
            // Turns of one session arrive strictly forward in time.
            for w in turns.windows(2) {
                assert!(w[0].submitted_at < w[1].submitted_at);
            }
            if turns.len() > 1 {
                multi += 1;
            }
        }
        assert!(multi > 0, "turns_mean 3 should yield multi-turn sessions");
    }

    #[test]
    fn session_trace_deterministic_double_run() {
        let make = |seed| {
            let mut g =
                Generator::new(NodeId(1), vec![Phase::new(0.0, 400.0, 3.0)])
                    .with_sessions(SessionProfile {
                        turns_mean: 4.0,
                        ..Default::default()
                    });
            let mut rng = Rng::new(seed);
            g.session_trace(&mut rng)
                .iter()
                .map(|r| {
                    (
                        r.id.seq,
                        r.session,
                        r.prompt_tokens,
                        r.output_tokens,
                        (r.submitted_at * 1e9) as i64,
                        (r.ttft_deadline * 1e9) as i64,
                    )
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(make(9), make(9));
        assert_ne!(make(9), make(10));
    }

    #[test]
    fn session_profile_check_rejects_bad_knobs() {
        assert!(SessionProfile::default().check().is_ok());
        let bad = SessionProfile { turns_mean: 0.5, ..Default::default() };
        assert!(bad.check().is_err());
        let bad = SessionProfile { max_turns: 0, ..Default::default() };
        assert!(bad.check().is_err());
        let bad = SessionProfile { think_mean: -1.0, ..Default::default() };
        assert!(bad.check().is_err());
        let bad = SessionProfile { ttft_floor: 0.0, ..Default::default() };
        assert!(bad.check().is_err());
    }

    #[test]
    fn trace_deterministic_in_seed() {
        let make = |seed| {
            let mut g =
                Generator::new(NodeId(0), vec![Phase::new(0.0, 500.0, 2.0)]);
            let mut rng = Rng::new(seed);
            g.trace(&mut rng)
                .iter()
                .map(|r| (r.id.seq, r.prompt_tokens, r.output_tokens))
                .collect::<Vec<_>>()
        };
        assert_eq!(make(7), make(7));
        assert_ne!(make(7), make(8));
    }
}
