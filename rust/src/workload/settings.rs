//! The paper's experimental settings (Appendix C, Table 3), verbatim.
//!
//! Each setting lists per-node (model, GPU, backend) plus the piecewise
//! Poisson request schedule. These drive Figure 4 and Table 2.

use super::Phase;
use crate::backend::{Gpu, ModelClass, Profile, ServingStack};

/// Which Table-3 setting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SettingId {
    S1,
    S2,
    S3,
    S4,
}

impl SettingId {
    pub const ALL: [SettingId; 4] =
        [SettingId::S1, SettingId::S2, SettingId::S3, SettingId::S4];

    pub fn name(self) -> &'static str {
        match self {
            SettingId::S1 => "Setting 1",
            SettingId::S2 => "Setting 2",
            SettingId::S3 => "Setting 3",
            SettingId::S4 => "Setting 4",
        }
    }
}

/// One row of Table 3.
#[derive(Debug, Clone)]
pub struct NodeSpec {
    pub model: ModelClass,
    pub gpu: Gpu,
    pub stack: ServingStack,
    pub phases: Vec<Phase>,
}

impl NodeSpec {
    pub fn profile(&self) -> Profile {
        Profile::derive(self.model, self.gpu, self.stack)
    }

    pub fn describe(&self) -> String {
        format!(
            "{} on {} ({})",
            self.model.name(),
            self.gpu.name(),
            self.stack.name()
        )
    }
}

/// A complete experimental setting.
#[derive(Debug, Clone)]
pub struct Setting {
    pub id: SettingId,
    pub nodes: Vec<NodeSpec>,
    /// Experiment horizon (Table 3 schedules end at 750 s).
    pub horizon: f64,
}

impl Setting {
    pub fn get(id: SettingId) -> Setting {
        use Gpu::*;
        use ModelClass::*;
        use ServingStack::*;

        let spec = |model, gpu, stack, phases| NodeSpec {
            model,
            gpu,
            stack,
            phases,
        };
        let ph = |from: f64, to: f64, ia: f64| Phase::new(from, to, ia);

        let nodes = match id {
            // Table 3, Setting 1: homogeneous Qwen3-8B on ADA6000/SGLang.
            SettingId::S1 => vec![
                spec(Qwen3_8B, Ada6000, SgLang,
                     vec![ph(0.0, 300.0, 5.0), ph(300.0, 750.0, 20.0)]),
                spec(Qwen3_8B, Ada6000, SgLang, vec![ph(0.0, 750.0, 20.0)]),
                spec(Qwen3_8B, Ada6000, SgLang, vec![ph(0.0, 750.0, 20.0)]),
                spec(Qwen3_8B, Ada6000, SgLang,
                     vec![ph(0.0, 450.0, 20.0), ph(450.0, 750.0, 5.0)]),
            ],
            // Setting 2: mixed 8B/4B.
            SettingId::S2 => vec![
                spec(Qwen3_8B, Ada6000, SgLang,
                     vec![ph(0.0, 300.0, 4.0), ph(300.0, 750.0, 20.0)]),
                spec(Qwen3_8B, Ada6000, SgLang, vec![ph(0.0, 750.0, 20.0)]),
                spec(Qwen3_4B, Rtx3090, SgLang, vec![ph(0.0, 750.0, 30.0)]),
                spec(Qwen3_4B, Rtx3090, SgLang,
                     vec![ph(0.0, 450.0, 30.0), ph(450.0, 750.0, 6.0)]),
            ],
            // Setting 3: heterogeneous models, GPUs and stacks.
            SettingId::S3 => vec![
                spec(Qwen3_32B, A100x4, SgLang,
                     vec![ph(0.0, 300.0, 2.0), ph(300.0, 750.0, 6.0)]),
                spec(Qwen3_8B, L40S, SgLang, vec![ph(0.0, 750.0, 15.0)]),
                spec(DeepSeekQwen7B, Rtx3090, Vllm, vec![ph(0.0, 750.0, 30.0)]),
                spec(Llama31_8B, Ada6000, Vllm,
                     vec![ph(0.0, 450.0, 15.0), ph(450.0, 750.0, 5.0)]),
            ],
            // Setting 4: eight nodes, the largest mix.
            SettingId::S4 => vec![
                spec(Llama31_8B, L40S, Vllm, vec![ph(0.0, 750.0, 9.0)]),
                spec(Llama31_8B, L40S, Vllm,
                     vec![ph(0.0, 450.0, 6.0), ph(450.0, 750.0, 12.0)]),
                spec(DeepSeekQwen7B, Ada6000, Vllm,
                     vec![ph(0.0, 300.0, 6.0), ph(300.0, 750.0, 12.0)]),
                spec(DeepSeekQwen7B, Ada6000, Vllm,
                     vec![ph(0.0, 450.0, 12.0), ph(450.0, 750.0, 6.0)]),
                spec(Qwen3_4B, Rtx4090, SgLang, vec![ph(0.0, 750.0, 12.0)]),
                spec(Qwen3_4B, Rtx4090, SgLang,
                     vec![ph(0.0, 450.0, 10.0), ph(450.0, 750.0, 20.0)]),
                spec(Qwen3_4B, Rtx3090, SgLang,
                     vec![ph(0.0, 300.0, 20.0), ph(300.0, 750.0, 10.0)]),
                spec(Qwen3_4B, Rtx3090, SgLang,
                     vec![ph(0.0, 300.0, 20.0), ph(300.0, 750.0, 10.0)]),
            ],
        };
        Setting { id, nodes, horizon: 750.0 }
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_counts_match_table3() {
        assert_eq!(Setting::get(SettingId::S1).num_nodes(), 4);
        assert_eq!(Setting::get(SettingId::S2).num_nodes(), 4);
        assert_eq!(Setting::get(SettingId::S3).num_nodes(), 4);
        assert_eq!(Setting::get(SettingId::S4).num_nodes(), 8);
    }

    #[test]
    fn horizons_are_750s() {
        for id in SettingId::ALL {
            let s = Setting::get(id);
            assert_eq!(s.horizon, 750.0);
            for n in &s.nodes {
                for p in &n.phases {
                    assert!(p.to <= 750.0);
                    assert!(p.from < p.to);
                    assert!(p.inter_arrival > 0.0);
                }
            }
        }
    }

    #[test]
    fn setting1_burst_structure() {
        // Node 1 bursts early (1/λ = 5 s), node 4 bursts late (1/λ = 5 s).
        let s = Setting::get(SettingId::S1);
        assert_eq!(s.nodes[0].phases[0].inter_arrival, 5.0);
        assert_eq!(s.nodes[3].phases[1].inter_arrival, 5.0);
        assert_eq!(s.nodes[3].phases[1].from, 450.0);
    }

    #[test]
    fn profiles_derivable_for_all_settings() {
        for id in SettingId::ALL {
            for n in &Setting::get(id).nodes {
                let p = n.profile();
                assert!(p.decode_tok_s > 0.0, "{}", n.describe());
            }
        }
    }
}
