//! Byzantine-robustness integration tests: gossip-borne junk, the signed
//! work-receipt settlement gate, reputation-driven quarantine of
//! free-riders, and determinism of a defended world under attack. The
//! attacker policies live in `wwwserve::policy::byzantine`; the defenses
//! in `wwwserve::reputation` (see its threat-model table).

use std::sync::{Arc, Mutex};

use wwwserve::backend::{Backend, Profile, SimBackend};
use wwwserve::config::parse_experiment;
use wwwserve::coordinator::{Action, Event, LedgerManager, Message, Node};
use wwwserve::crypto::{KeyStore, NodeKey};
use wwwserve::gossip::GossipConfig;
use wwwserve::latency::LatencyConfig;
use wwwserve::ledger::{Ledger, SharedLedger};
use wwwserve::policy::{FreeRider, NodePolicy, ResultFaker, SystemPolicy};
use wwwserve::reputation::{DefenseConfig, DefenseState};
use wwwserve::sim::World;
use wwwserve::types::{Request, RequestId};
use wwwserve::NodeId;

fn mk_node(id: u32, shared: &Arc<Mutex<SharedLedger>>) -> Node {
    Node::new(
        NodeId(id),
        NodePolicy::default(),
        SystemPolicy::default(),
        Box::new(SimBackend::new(Profile::test(50.0, 8))),
        LedgerManager::shared(shared.clone()),
        GossipConfig::default(),
        7,
        0.0,
    )
}

/// Arm a node's defenses with network-consistent key material.
fn arm(node: &mut Node, seed: u64, n: u32) {
    node.set_defenses(DefenseState::new(
        DefenseConfig { enabled: true, ..Default::default() },
        NodeKey::derive(seed, node.id),
        KeyStore::for_network(seed, n),
    ));
}

fn req(origin: u32, seq: u64, at: f64, slo: f64) -> Request {
    Request {
        id: RequestId { origin: NodeId(origin), seq },
        prompt_tokens: 50,
        output_tokens: 100,
        submitted_at: at,
        slo_deadline: slo,
        synthetic: false,
        payload: vec![],
        session: 0,
        ttft_deadline: f64::INFINITY,
    }
}

fn find_send(actions: &[Action], kind: &str) -> Option<(NodeId, Message)> {
    actions.iter().find_map(|a| match a {
        Action::Send { to, msg } if msg.kind() == kind => {
            Some((*to, msg.clone()))
        }
        _ => None,
    })
}

/// Run the probe -> accept -> delegate handshake from `n0` to `n1` for one
/// request submitted at `t`. Returns None when n0 never probed (the
/// candidate set was empty — e.g. the only peer is quarantined).
fn delegate_once(
    n0: &mut Node,
    n1: &mut Node,
    seq: u64,
    t: f64,
    slo: f64,
) -> Option<Vec<Action>> {
    let a = n0.handle(Event::UserRequest(req(0, seq, t, slo)), t);
    let (to, probe) = find_send(&a, "probe")?;
    assert_eq!(to, NodeId(1));
    let a = n1.handle(Event::Message { from: NodeId(0), msg: probe }, t + 0.1);
    let (_, accept) =
        find_send(&a, "probe_accept").expect("probe must be accepted");
    let a =
        n0.handle(Event::Message { from: NodeId(1), msg: accept }, t + 0.2);
    let (_, delegate) =
        find_send(&a, "delegate").expect("accept must trigger the delegate");
    Some(n1.handle(Event::Message { from: NodeId(0), msg: delegate }, t + 0.3))
}

// ---- gossip-borne junk ------------------------------------------------------

#[test]
fn junk_gossip_rtts_never_panic_and_bump_the_reject_counter() {
    // Malformed piggybacked RTT rows (NaN, negative, absurd) must be
    // rejected outright — with a counter bump, never a panic — even with
    // defenses OFF: the junk guard is basic input validation, not a
    // configurable defense.
    let shared = Arc::new(Mutex::new(SharedLedger::new()));
    let mut n = mk_node(0, &shared);
    n.set_locality(
        0,
        vec![vec![0.005, 0.080], vec![0.080, 0.005]],
        LatencyConfig::default(),
    );
    let a = n.handle(
        Event::Message {
            from: NodeId(9),
            msg: Message::GossipDelta {
                delta: vec![],
                heartbeats: vec![],
                rtts: vec![
                    (0, 1, f64::NAN),
                    (0, 1, f64::INFINITY),
                    (0, 1, -1.0),
                    (0, 1, 1.0e9),
                    (0, 1, 0.065), // the one well-formed row
                ],
                rep: vec![],
            },
        },
        1.0,
    );
    drop(a);
    assert_eq!(n.stats.rtts_rejected, 4, "four junk rows rejected");
    assert_eq!(n.stats.rtts_capped, 0, "defenses off: no hearsay capping");
    // The clean row still merged: the estimate moved off the 80 ms prior.
    let est = n.latency_estimator().unwrap().expected_from_me(1, 1.0);
    assert!(est < 0.080, "clean row ignored: {est}");
}

#[test]
fn hearsay_cap_clamps_latency_liar_rows_when_defended() {
    // A LatencyLiar gossips a near-zero RTT for a trans-oceanic path. With
    // defenses on, the merged cell is clamped to within hearsay_cap of the
    // node's own expectation, so the lie cannot collapse the estimate.
    let shared = Arc::new(Mutex::new(SharedLedger::new()));
    let mut n = mk_node(0, &shared);
    arm(&mut n, 7, 2);
    n.set_locality(
        0,
        vec![vec![0.005, 0.080], vec![0.080, 0.005]],
        LatencyConfig::default(),
    );
    n.handle(
        Event::Message {
            from: NodeId(9),
            msg: Message::GossipDelta {
                delta: vec![],
                heartbeats: vec![],
                rtts: vec![(0, 1, 0.0005)], // plausible-looking lie
                rep: vec![],
            },
        },
        1.0,
    );
    assert_eq!(n.stats.rtts_capped, 1, "the lie must be clamped");
    assert_eq!(n.stats.rtts_rejected, 0);
    let est = n.latency_estimator().unwrap().expected_from_me(1, 1.0);
    // Clamp floor is own/cap = 0.080 / 3; the EWMA can only move toward
    // that, never to the liar's half-millisecond.
    assert!(
        est >= 0.080 / 3.0 * 0.9,
        "hearsay cap failed to bound the lie: {est}"
    );
}

// ---- signed work receipts ---------------------------------------------------

#[test]
fn honest_receipted_work_settles_and_pays() {
    let shared = Arc::new(Mutex::new(SharedLedger::new()));
    let mut n0 = mk_node(0, &shared);
    let mut n1 = mk_node(1, &shared);
    arm(&mut n0, 7, 2);
    arm(&mut n1, 7, 2);
    n0.policy.target_utilization = 0.0;
    n0.policy.offload_freq = 1.0;
    n0.system.duel_rate = 0.0;
    n1.policy.accept_freq = 1.0;
    n0.view.merge(&[(NodeId(1), 1, true, 0, 0)], 0.0);

    let bal1 = shared.lock().unwrap().balance(NodeId(1));
    delegate_once(&mut n0, &mut n1, 0, 0.0, 60.0).expect("probe sent");
    // Run the executor's backend to completion: the response must carry a
    // signed receipt.
    let a = n1.handle(Event::BackendWake, 100.0);
    let (_, resp) = find_send(&a, "delegate_response").expect("response");
    let Message::DelegateResponse { ref receipt, .. } = resp else {
        unreachable!()
    };
    assert!(receipt.is_some(), "defended executor must attach a receipt");
    let a = n0.handle(Event::Message { from: NodeId(1), msg: resp }, 100.1);
    assert!(a.iter().any(|x| matches!(x, Action::Done(_))));
    assert_eq!(n0.stats.receipt_rejects, 0);
    let paid = shared.lock().unwrap().balance(NodeId(1)) - bal1;
    assert_eq!(paid, SystemPolicy::default().base_reward, "work paid once");
}

#[test]
fn result_faker_receipt_is_rejected_and_never_paid() {
    let shared = Arc::new(Mutex::new(SharedLedger::new()));
    let mut n0 = mk_node(0, &shared);
    let mut n1 = mk_node(1, &shared);
    arm(&mut n0, 7, 2);
    arm(&mut n1, 7, 2);
    n1.set_participation(Box::new(ResultFaker::default()));
    n0.policy.target_utilization = 0.0;
    n0.policy.offload_freq = 1.0;
    n0.system.duel_rate = 0.0;
    n0.view.merge(&[(NodeId(1), 1, true, 0, 0)], 0.0);

    let bal1 = shared.lock().unwrap().balance(NodeId(1));
    delegate_once(&mut n0, &mut n1, 0, 0.0, 60.0).expect("probe sent");
    let a = n1.handle(Event::BackendWake, 100.0);
    let (_, resp) = find_send(&a, "delegate_response").expect("response");
    let Message::DelegateResponse { ref receipt, .. } = resp else {
        unreachable!()
    };
    // The faker does ship a receipt — signed over content it never
    // produced. Settlement must catch the digest mismatch.
    assert!(receipt.is_some());
    let fallback_before = n0.stats.fallback_local;
    let a = n0.handle(Event::Message { from: NodeId(1), msg: resp }, 100.1);
    assert!(
        !a.iter().any(|x| matches!(x, Action::Done(_))),
        "faked work must not complete the request"
    );
    assert_eq!(n0.stats.receipt_rejects, 1);
    assert_eq!(n0.stats.fallback_local, fallback_before + 1);
    assert_eq!(n0.backend().running_len(), 1, "re-served locally");
    assert_eq!(
        shared.lock().unwrap().balance(NodeId(1)),
        bal1,
        "faked work must never be paid"
    );
    // And the faker's reputation took the ReceiptFail hit.
    let eff = n0.defense_state().rep.effective(NodeId(1), 100.1);
    assert!(eff < 0.5, "receipt failure must crater reputation: {eff}");
}

#[test]
fn unreceipted_work_is_never_paid_when_defenses_are_on() {
    // The executor is honest but runs no defense layer (e.g. a laggard
    // deployment): its bare response cannot settle against a defended
    // requester — payment is withheld and the request re-served locally.
    let shared = Arc::new(Mutex::new(SharedLedger::new()));
    let mut n0 = mk_node(0, &shared);
    let mut n1 = mk_node(1, &shared);
    arm(&mut n0, 7, 2);
    n0.policy.target_utilization = 0.0;
    n0.policy.offload_freq = 1.0;
    n0.system.duel_rate = 0.0;
    n1.policy.accept_freq = 1.0;
    n0.view.merge(&[(NodeId(1), 1, true, 0, 0)], 0.0);

    let bal1 = shared.lock().unwrap().balance(NodeId(1));
    delegate_once(&mut n0, &mut n1, 0, 0.0, 60.0).expect("probe sent");
    let a = n1.handle(Event::BackendWake, 100.0);
    let (_, resp) = find_send(&a, "delegate_response").expect("response");
    let Message::DelegateResponse { ref receipt, .. } = resp else {
        unreachable!()
    };
    assert!(receipt.is_none(), "undefended executor sends no receipt");
    let a = n0.handle(Event::Message { from: NodeId(1), msg: resp }, 100.1);
    assert!(!a.iter().any(|x| matches!(x, Action::Done(_))));
    assert_eq!(n0.stats.receipt_rejects, 1);
    assert_eq!(shared.lock().unwrap().balance(NodeId(1)), bal1);
}

// ---- reputation quarantine --------------------------------------------------

#[test]
fn free_rider_is_quarantined_after_repeated_timeouts() {
    let shared = Arc::new(Mutex::new(SharedLedger::new()));
    let mut n0 = mk_node(0, &shared);
    let mut n1 = mk_node(1, &shared);
    arm(&mut n0, 7, 2);
    n1.set_participation(Box::new(FreeRider));
    n0.policy.target_utilization = 0.0;
    n0.policy.offload_freq = 1.0;
    n0.system.duel_rate = 0.0;
    n0.view.merge(&[(NodeId(1), 1, true, 0, 0)], 0.0);

    // Short SLO so the response timeout (slo * 3) fires quickly.
    let slo = 1.0;
    let mut quarantined_stopped_probes = false;
    for k in 0..10u64 {
        let t = k as f64 * 5.0;
        match delegate_once(&mut n0, &mut n1, k, t, slo) {
            Some(dropped) => {
                // The free-rider accepted and then silently dropped the
                // work: nothing entered its backend.
                assert!(
                    !dropped
                        .iter()
                        .any(|x| matches!(x, Action::Send { .. })),
                    "free-rider must stay silent"
                );
                assert_eq!(n1.backend().running_len(), 0);
                // Past the response deadline the requester times out,
                // strikes the executor's reputation, and serves locally.
                n0.handle(Event::Tick, t + 0.2 + slo * 3.0 + 0.5);
            }
            None => {
                // No probe sent: the only candidate is quarantined.
                assert!(
                    n0.defense_state().rep.is_quarantined(NodeId(1)),
                    "probes stopped for a non-quarantine reason"
                );
                quarantined_stopped_probes = true;
                break;
            }
        }
    }
    assert!(
        quarantined_stopped_probes,
        "free-rider was never quarantined out of the candidate set \
         (score: {})",
        n0.defense_state().rep.effective(NodeId(1), 50.0)
    );
    assert!(n0.stats.quarantines >= 1, "quarantine transition not counted");
    assert!(n0.stats.fallback_local >= 4, "timeouts must fall back locally");
}

// ---- whole-world determinism under attack -----------------------------------

#[test]
fn defended_byzantine_world_replays_deterministically() {
    // A two-region world where a third of the servers misbehave, with the
    // full defense stack armed: the run must be bit-reproducible from the
    // seed, and the defenses must visibly engage (receipt rejections from
    // the faker, quarantines of the free-riders).
    let cfg = r#"{
        "seed": 77, "horizon": 300,
        "system": { "duel_rate": 0.0 },
        "defenses": { "enabled": true },
        "topology": {
            "regions": ["us", "eu"],
            "intra": { "latency": [0.002, 0.010] },
            "inter": { "latency": [0.040, 0.080] },
            "fleet": [
                { "region": "us", "count": 1, "policy": "requester_only",
                  "node": { "policy": { "latency_penalty": 20.0 } },
                  "schedule": [ {"from": 0, "to": 300,
                                 "inter_arrival": 2} ],
                  "lengths": { "output_mean": 600, "output_sigma": 0.5 } },
                { "region": "us", "count": 2,
                  "node": { "policy": { "stake": 20,
                                        "accept_freq": 1.0 } } },
                { "region": "us", "count": 2, "byzantine": "free_rider",
                  "node": { "policy": { "stake": 20,
                                        "accept_freq": 1.0 } } },
                { "region": "eu", "count": 1, "policy": "requester_only",
                  "node": { "policy": { "latency_penalty": 20.0 } },
                  "schedule": [ {"from": 0, "to": 300,
                                 "inter_arrival": 2} ],
                  "lengths": { "output_mean": 600, "output_sigma": 0.5 } },
                { "region": "eu", "count": 2,
                  "node": { "policy": { "stake": 20,
                                        "accept_freq": 1.0 } } },
                { "region": "eu", "count": 1, "byzantine": "result_faker",
                  "node": { "policy": { "stake": 40,
                                        "accept_freq": 1.0 } } }
            ]
        }
    }"#;
    let go = || {
        let e = parse_experiment(cfg).expect("config parses");
        assert!(e.world.defenses.enabled);
        assert_eq!(
            e.setups.iter().filter(|s| s.byzantine.is_some()).count(),
            3,
            "three attacker nodes stamped"
        );
        let mut w = World::new(e.world.clone(), e.setups.clone());
        w.run_until(900.0);
        let receipt_rejects: u64 = (0..w.num_nodes())
            .map(|i| w.node(i).stats.receipt_rejects)
            .sum();
        let quarantines: u64 = (0..w.num_nodes())
            .map(|i| w.node(i).stats.quarantines)
            .sum();
        (
            w.recorder.len(),
            (w.recorder.mean_latency() * 1e9) as u64,
            w.messages_sent,
            w.bytes_sent,
            w.messages_dropped,
            w.credit_totals()
                .iter()
                .map(|c| (c * 1e6) as u64)
                .collect::<Vec<_>>(),
            receipt_rejects,
            quarantines,
        )
    };
    let a = go();
    assert!(a.0 > 50, "attacked world barely ran: {} records", a.0);
    assert!(a.6 > 0, "the result faker was never caught at settlement");
    assert!(a.7 > 0, "no free-rider was ever quarantined");
    let b = go();
    assert_eq!(a, b, "defended byzantine world is not deterministic");
}
