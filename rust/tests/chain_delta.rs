//! Delta chain-sync tests: the blockchain-mode anti-entropy path ships
//! only the missing block suffix (`Message::ChainDelta`) when the
//! requester's chain is a prefix of the responder's, falling back to the
//! full `ChainSnapshot` otherwise. Mirrors `rust/tests/delta_gossip.rs`:
//! the full-snapshot protocol is kept as the correctness oracle, and both
//! protocols must converge every replica to an identical, auditable chain
//! under churn and partitions — the delta path just pays far fewer bytes.

use wwwserve::backend::Profile;
use wwwserve::coordinator::LedgerManager;
use wwwserve::crypto::KeyStore;
use wwwserve::policy::NodePolicy;
use wwwserve::sim::{LedgerMode, NodeSetup, World, WorldConfig};
use wwwserve::topology::{LinkChange, LinkProfile, Topology};
use wwwserve::workload::{Generator, LengthDist, Phase};
use wwwserve::NodeId;

fn lengths() -> LengthDist {
    LengthDist { output_mean: 1200.0, output_sigma: 0.5, ..Default::default() }
}

fn paying_setups(n: usize, ia: f64, horizon: f64) -> Vec<NodeSetup> {
    (0..n)
        .map(|i| {
            NodeSetup::new(
                Profile::test(40.0, 16),
                NodePolicy { accept_freq: 1.0, ..Default::default() },
            )
            .with_generator(
                Generator::new(
                    NodeId(i as u32),
                    vec![Phase::new(0.0, horizon, ia)],
                )
                .with_lengths(lengths()),
            )
        })
        .collect()
}

/// Every replica's chain, by length and head-by-audit: replicas must end
/// identical, and the chain must re-validate from genesis.
fn chain_lengths_audited(w: &World, n: usize, seed: u64) -> Vec<usize> {
    let keys = KeyStore::for_network(seed, n as u32);
    (0..n)
        .map(|i| match w.node(i).ledger() {
            LedgerManager::Chain(r) => {
                assert!(r.chain.audit(&keys), "node {i}: chain fails audit");
                r.chain.len()
            }
            LedgerManager::Shared(_) => panic!("blockchain mode expected"),
        })
        .collect()
}

/// Late-joiner churn under both sync protocols: replicas converge to the
/// same audited chain either way (the fallback is the oracle), and delta
/// sync pays strictly fewer chain-sync bytes.
#[test]
fn churn_converges_under_both_protocols_and_delta_cuts_bytes() {
    let seed = 11u64;
    let run = |delta_sync: bool| -> (World, Vec<usize>) {
        let mut setups = paying_setups(4, 6.0, 300.0);
        setups.push(
            NodeSetup::new(
                Profile::test(40.0, 16),
                NodePolicy { accept_freq: 1.0, ..Default::default() },
            )
            .offline(),
        );
        let cfg = WorldConfig {
            seed,
            ledger: LedgerMode::Blockchain,
            chain_delta_sync: delta_sync,
            ..Default::default()
        };
        let mut w = World::new(cfg, setups);
        // The late joiner catches a long-established chain — the sync-path
        // stress case: full mode re-ships the whole replica, delta mode
        // ships suffixes.
        w.schedule_join(4, 100.0);
        w.run_until(4000.0);
        let lens = chain_lengths_audited(&w, 5, seed);
        (w, lens)
    };

    let (full_w, full_lens) = run(false);
    let (delta_w, delta_lens) = run(true);

    for lens in [&full_lens, &delta_lens] {
        assert!(lens[0] > 1, "no blocks were ledgered: {lens:?}");
        for l in lens.iter() {
            assert_eq!(*l, lens[0], "replicas diverged: {lens:?}");
        }
    }
    assert!(
        full_w.chain_sync_messages_sent > 0
            && delta_w.chain_sync_messages_sent > 0,
        "chain sync never ran"
    );
    assert!(
        delta_w.chain_sync_bytes_sent < full_w.chain_sync_bytes_sent,
        "delta sync did not cut bytes: {} vs {}",
        delta_w.chain_sync_bytes_sent,
        full_w.chain_sync_bytes_sent
    );
    // The headline ratio assert (≥5x at n=500) lives in
    // benches/fleet_scale.rs; even this small world must show a clear cut.
    assert!(
        delta_w.chain_sync_bytes_sent * 2 <= full_w.chain_sync_bytes_sent,
        "expected >= 2x chain-sync byte cut, got {}/{}",
        full_w.chain_sync_bytes_sent,
        delta_w.chain_sync_bytes_sent
    );
}

/// Partition/heal: an asymmetric 3+1 split keeps the majority side at
/// quorum, so it goes on committing blocks while the minority node stalls
/// (and possibly diverges via solo self-commits once the far side ages
/// out). After the heal, anti-entropy must reconcile every replica to one
/// audited chain — the anchored case rides `ChainDelta`, divergence falls
/// back to the full `ChainSnapshot` — under both protocols.
#[test]
fn partition_heal_reconciles_under_both_protocols() {
    let seed = 42u64;
    let run = |delta_sync: bool| -> Vec<usize> {
        let topo = Topology::builder()
            .region("west")
            .region("east")
            .default_intra(LinkProfile::new(0.001, 0.004))
            .link("west", "east", LinkProfile::new(0.040, 0.060))
            .nodes("west", 3)
            .nodes("east", 1)
            .event("west", "east", 50.0, LinkChange::Partition)
            .event("west", "east", 150.0, LinkChange::Heal)
            .build();
        let mut cfg = WorldConfig {
            seed,
            ledger: LedgerMode::Blockchain,
            topology: Some(topo),
            chain_delta_sync: delta_sync,
            ..Default::default()
        };
        // Generous suspicion window so the partition itself (not liveness
        // aging) is the only isolation mechanism at play.
        cfg.gossip.suspect_after = 30.0;
        let setups = paying_setups(4, 5.0, 200.0);
        let mut w = World::new(cfg, setups);
        w.run_until(3000.0);
        chain_lengths_audited(&w, 4, seed)
    };
    for delta_sync in [false, true] {
        let lens = run(delta_sync);
        assert!(lens[0] > 1, "delta_sync={delta_sync}: no blocks: {lens:?}");
        for l in &lens {
            assert_eq!(
                *l, lens[0],
                "delta_sync={delta_sync}: replicas diverged after heal: {lens:?}"
            );
        }
    }
}
