//! Delta-gossip protocol tests: convergence equivalence against the
//! full-digest protocol under partitions, leaves and heals (the
//! correctness oracle the ISSUE demands), plus the world-level byte
//! savings the fleet-scale work is built on.

use wwwserve::backend::Profile;
use wwwserve::gossip::{Digest, GossipConfig, PeerView};
use wwwserve::policy::NodePolicy;
use wwwserve::sim::{NodeSetup, World, WorldConfig};
use wwwserve::topology::{LinkChange, LinkProfile, Topology};
use wwwserve::util::rng::Rng;
use wwwserve::NodeId;

#[derive(Clone, Copy, PartialEq)]
enum Protocol {
    /// Every exchange ships the full digest (the seed protocol).
    Full,
    /// Deltas + heartbeat pairs, full digest every `AE`-th round.
    Delta,
}

const N: usize = 16;
const AE: u64 = 6;
/// Scripted scenario: heartbeat rounds 1..=30; the two halves are
/// partitioned during rounds 10..20; node 3 gracefully leaves at round 12
/// (mid-partition) and rejoins at round 24.
const ACTIVE_ROUNDS: usize = 30;
const LEAVER: usize = 3;

fn cfg() -> GossipConfig {
    GossipConfig {
        interval: 1.0,
        fanout: 2,
        suspect_after: 5.0,
        anti_entropy_every: AE,
    }
}

fn cross(a: usize, b: usize) -> bool {
    (a < N / 2) != (b < N / 2)
}

fn leaver_down(round: usize) -> bool {
    (12..24).contains(&round)
}

/// One push-pull exchange from `i` to `t` through the given protocol form.
/// Mirrors the node's communication manager: the sender builds its payload
/// (advancing delta floors optimistically) even when the fabric then drops
/// the message — exactly what a partitioned link does to a real node.
fn exchange(
    views: &mut [PeerView],
    i: usize,
    t: usize,
    full: bool,
    dropped: bool,
    receiver_down: bool,
    now: f64,
) {
    let tid = NodeId(t as u32);
    let iid = NodeId(i as u32);
    if full {
        let d = views[i].digest();
        views[i].mark_synced(tid);
        if dropped || receiver_down {
            return;
        }
        views[t].merge(&d, now);
        let back = views[t].digest();
        views[t].mark_synced(iid);
        views[i].merge(&back, now);
    } else {
        let (delta, hbs) = views[i].delta_for(tid, now);
        if dropped || receiver_down || (delta.is_empty() && hbs.is_empty()) {
            return;
        }
        let mut fresh = views[t].merge(&delta, now);
        fresh.extend(views[t].merge_heartbeats(&hbs, now));
        fresh.sort_unstable();
        let (rd, rh) = views[t].delta_for_excluding(iid, now, &fresh);
        if rd.is_empty() && rh.is_empty() {
            return;
        }
        views[i].merge(&rd, now);
        views[i].merge_heartbeats(&rh, now);
    }
}

/// Run the scripted scenario under one protocol. Returns the final digests
/// after quiescing through the protocol's own full-digest anti-entropy
/// form (an all-pairs sweep — the correctness oracle), plus the expected
/// per-node heartbeat counts accumulated by the script.
fn run_protocol(proto: Protocol, seed: u64) -> (Vec<Digest>, Vec<u64>) {
    let mut views: Vec<PeerView> =
        (0..N).map(|i| PeerView::new(NodeId(i as u32), cfg(), 0.0)).collect();
    // The simulator's bootstrap: everyone seeds everyone, then seals.
    for i in 0..N {
        for j in 0..N {
            if i != j {
                views[i].add_seed(NodeId(j as u32), 0, 0, 0.0);
            }
        }
        views[i].seal_bootstrap();
    }
    let mut expected_version = vec![1u64; N];
    let mut rng = Rng::new(seed);

    for round in 1..=ACTIVE_ROUNDS {
        let now = round as f64;
        let partitioned = (10..20).contains(&round);
        if round == 12 {
            views[LEAVER].announce_leave(now);
            expected_version[LEAVER] += 1;
            // The goodbye reaches one same-side neighbour before shutdown.
            let goodbye = views[LEAVER].digest();
            views[LEAVER].mark_synced(NodeId(2));
            views[2].merge(&goodbye, now);
        }
        for i in 0..N {
            if i == LEAVER && leaver_down(round) {
                continue;
            }
            views[i].heartbeat(now);
            expected_version[i] += 1;
        }
        for i in 0..N {
            if i == LEAVER && leaver_down(round) {
                continue;
            }
            let full_round = match proto {
                Protocol::Full => true,
                Protocol::Delta => round as u64 % AE == 1,
            };
            let (targets, suspect) =
                views[i].pick_round_targets(&mut rng, now);
            for t in targets {
                let t = t.0 as usize;
                exchange(
                    &mut views,
                    i,
                    t,
                    full_round,
                    partitioned && cross(i, t),
                    t == LEAVER && leaver_down(round),
                    now,
                );
            }
            if let Some(s) = suspect {
                // Suspicion probes always carry the full digest.
                let s = s.0 as usize;
                exchange(
                    &mut views,
                    i,
                    s,
                    true,
                    partitioned && cross(i, s),
                    s == LEAVER && leaver_down(round),
                    now,
                );
            }
        }
    }

    // Quiesce: no more heartbeats; an all-pairs sweep through the
    // protocol's full-digest anti-entropy form. Both protocols use the
    // same wire form here (that is the point of keeping it), so any
    // divergence below comes from what the delta rounds did to the state.
    let now = (ACTIVE_ROUNDS + 1) as f64;
    for i in 0..N {
        for j in 0..N {
            if i != j {
                let d = views[i].digest();
                views[i].mark_synced(NodeId(j as u32));
                views[j].merge(&d, now);
            }
        }
    }
    (views.iter().map(|v| v.digest()).collect(), expected_version)
}

/// The ISSUE's correctness oracle: delta gossip and full-digest gossip,
/// driven through the same partition/leave/heal script, must converge to
/// bit-identical `PeerView`s.
#[test]
fn delta_and_full_converge_bit_identically() {
    for seed in 0..8u64 {
        let (full_views, expect_full) = run_protocol(Protocol::Full, seed);
        let (delta_views, expect_delta) = run_protocol(Protocol::Delta, seed);
        assert_eq!(expect_full, expect_delta, "script must be identical");
        for i in 0..N {
            assert_eq!(
                full_views[i], delta_views[i],
                "seed {seed}: node {i} diverged between protocols"
            );
        }
        // Global convergence: every node ends with the same view, and the
        // versions are exactly the per-node heartbeat counts — deltas must
        // neither lose updates (sweep-repaired ones excepted) nor invent
        // versions the origin never produced.
        for i in 1..N {
            assert_eq!(delta_views[0], delta_views[i], "seed {seed}");
        }
        for (node, version, online, _, _) in &delta_views[0] {
            assert_eq!(
                *version, expect_full[node.0 as usize],
                "seed {seed}: version drift for {node}"
            );
            assert!(*online, "seed {seed}: {node} ended offline");
        }
    }
}

/// Mid-run (no oracle sweep) the delta protocol must keep liveness fresh:
/// membership is complete and the overwhelming share of peer pairs stays
/// within the suspicion window, leaver aside.
#[test]
fn delta_rounds_keep_liveness_fresh_without_oracle() {
    let mut views: Vec<PeerView> =
        (0..N).map(|i| PeerView::new(NodeId(i as u32), cfg(), 0.0)).collect();
    for i in 0..N {
        for j in 0..N {
            if i != j {
                views[i].add_seed(NodeId(j as u32), 0, 0, 0.0);
            }
        }
        views[i].seal_bootstrap();
    }
    let mut rng = Rng::new(5);
    let rounds = 40usize;
    for round in 1..=rounds {
        let now = round as f64;
        for v in views.iter_mut() {
            v.heartbeat(now);
        }
        for i in 0..N {
            let full_round = round as u64 % AE == 1;
            let (targets, suspect) =
                views[i].pick_round_targets(&mut rng, now);
            for t in targets {
                exchange(&mut views, i, t.0 as usize, full_round, false, false, now);
            }
            if let Some(s) = suspect {
                exchange(&mut views, i, s.0 as usize, true, false, false, now);
            }
        }
    }
    let now = rounds as f64;
    let mut alive_pairs = 0usize;
    for (i, v) in views.iter().enumerate() {
        assert_eq!(v.known(), N, "node {i} lost membership");
        for j in 0..N {
            if i != j && v.is_alive(NodeId(j as u32), now) {
                alive_pairs += 1;
            }
        }
    }
    let total = N * (N - 1);
    assert!(
        alive_pairs * 100 >= total * 90,
        "delta rounds starved liveness: {alive_pairs}/{total} pairs alive"
    );
}

/// World-level: at a 50-node fleet the delta protocol must strictly cut
/// gossip bytes vs. the full-digest baseline — by a wide margin, not
/// epsilon (the ISSUE's `bytes_sent` satellite).
#[test]
fn delta_gossip_cuts_gossip_bytes_at_n50() {
    let run = |anti_entropy_every: u64| -> (u64, u64, u64) {
        let mut cfg = WorldConfig { seed: 77, ..Default::default() };
        cfg.gossip.anti_entropy_every = anti_entropy_every;
        let setups: Vec<NodeSetup> = (0..50)
            .map(|_| {
                NodeSetup::new(Profile::test(40.0, 8), NodePolicy::default())
            })
            .collect();
        let mut w = World::new(cfg, setups);
        w.run_until(60.0);
        (w.gossip_bytes_sent, w.gossip_messages_sent, w.bytes_sent)
    };
    let (full_bytes, full_msgs, _) = run(1);
    let (delta_bytes, delta_msgs, delta_total) = run(32);
    assert!(full_msgs > 0 && delta_msgs > 0);
    assert!(delta_bytes <= delta_total);
    assert!(
        delta_bytes < full_bytes,
        "delta gossip did not reduce bytes: {delta_bytes} vs {full_bytes}"
    );
    assert!(
        delta_bytes * 3 <= full_bytes,
        "expected >= 3x gossip byte cut at n=50, got {full_bytes}/{delta_bytes}"
    );
}

/// Reuse the geo-topology partition/heal scenario at world level: under
/// both protocols (full baseline and delta), the partition splits the
/// views and the heal re-merges them — equivalent liveness outcomes.
#[test]
fn partition_heal_liveness_equivalent_across_protocols() {
    let run = |anti_entropy_every: u64| -> World {
        let topo = Topology::builder()
            .region("west")
            .region("east")
            .default_intra(LinkProfile::new(0.001, 0.004))
            .link("west", "east", LinkProfile::new(0.040, 0.060))
            .nodes("west", 2)
            .nodes("east", 2)
            .event("west", "east", 50.0, LinkChange::Partition)
            .event("west", "east", 120.0, LinkChange::Heal)
            .build();
        let mut cfg = WorldConfig {
            seed: 42,
            topology: Some(topo),
            ..Default::default()
        };
        cfg.gossip.anti_entropy_every = anti_entropy_every;
        let setups = (0..4)
            .map(|_| {
                NodeSetup::new(
                    Profile::test(40.0, 16),
                    NodePolicy { accept_freq: 1.0, ..Default::default() },
                )
            })
            .collect();
        World::new(cfg, setups)
    };
    for ae in [1u64, 32] {
        let mut w = run(ae);
        w.run_until(110.0);
        let now = w.now();
        assert!(
            !w.node(0).view.is_alive(NodeId(2), now),
            "ae={ae}: partition did not split views"
        );
        assert!(w.node(0).view.is_alive(NodeId(1), now), "ae={ae}");
        w.run_until(300.0);
        let now = w.now();
        for (a, b) in [(0usize, 2u32), (2, 0), (1, 3), (3, 1)] {
            assert!(
                w.node(a).view.is_alive(NodeId(b), now),
                "ae={ae}: n{a} did not re-admit n{b} after heal"
            );
        }
    }
}
