//! Same-process double-run determinism stress tests.
//!
//! The pinned fingerprints in `rust/tests/replay_equivalence.rs` compare
//! scenario *variants* (defenses off ≡ baseline, policy object ≡ scalar
//! knob). This file attacks a different failure mode: run the *same*
//! scenario twice in one process and demand bit-identical fingerprints.
//! Rust's `HashMap` seeds its hasher per instance, so two Worlds built in
//! the same process visit any hash-ordered state in different orders —
//! a single unordered iteration on a sim-visible path (the D001 class in
//! `docs/determinism.md`) diverges *here* even when a lone run looks fine
//! and even when a process-per-run comparison happens to agree. This is
//! the dynamic complement to the static `detlint` pass.
//!
//! Every run re-parses its config from scratch, so config parsing and
//! World construction are inside the contract, not just the event loop.

use wwwserve::config::parse_experiment;
use wwwserve::sim::World;

const HORIZON: f64 = 400.0;

/// The geo_scale smoke scenario (same shape replay_equivalence pins): one
/// requester + two servers per region, offset diurnal peaks, us<->asia
/// partition at 150 s healed at 250 s.
fn geo_smoke_config() -> String {
    let mut groups = Vec::new();
    for (region, offset) in [("us", 0.0), ("eu", 100.0), ("asia", 200.0)] {
        groups.push(format!(
            r#"{{ "region": "{region}", "count": 1,
                 "node": {{
                   "profile": {{ "prefill_tok_s": 2000, "decode_tok_s": 40,
                                 "max_agg_decode_tok_s": 160,
                                 "max_batch": 4 }},
                   "policy": {{ "stake": 0, "offload_freq": 1.0,
                                "accept_freq": 0.0, "requester_only": true,
                                "latency_penalty": 50.0 }} }},
                 "diurnal": {{ "period": 300, "peak_inter_arrival": 2.5,
                               "off_inter_arrival": 25,
                               "offset": {offset} }},
                 "lengths": {{ "output_mean": 900,
                               "output_sigma": 0.5 }} }}"#
        ));
        groups.push(format!(
            r#"{{ "region": "{region}", "count": 2,
                 "node": {{
                   "profile": {{ "prefill_tok_s": 4000, "decode_tok_s": 45,
                                 "max_agg_decode_tok_s": 1080,
                                 "max_batch": 24 }},
                   "policy": {{ "stake": 20, "accept_freq": 1.0,
                                "latency_penalty": 50.0 }} }} }}"#
        ));
    }
    format!(
        r#"{{
            "seed": 2026,
            "horizon": {HORIZON},
            "system": {{ "duel_rate": 0.1 }},
            "topology": {{
                "regions": ["us", "eu", "asia"],
                "intra": {{ "latency": [0.002, 0.010] }},
                "inter": {{ "latency": [0.040, 0.080], "jitter": 0.005 }},
                "events": [
                    {{ "at": 150, "a": "us", "b": "asia",
                       "change": "partition" }},
                    {{ "at": 250, "a": "us", "b": "asia", "change": "heal" }}
                ],
                "fleet": [ {} ]
            }}
        }}"#,
        groups.join(", ")
    )
}

/// Splice an extra top-level config block in after the seed.
fn with_block(cfg: &str, block: &str) -> String {
    let out = cfg.replace("\"seed\": 2026,", &format!("\"seed\": 2026, {block},"));
    assert!(out.contains(block), "splice failed");
    out
}

/// Everything observable about a finished world, quantized for exact
/// comparison (same shape replay_equivalence pins).
type Fingerprint =
    (usize, u64, u64, u64, u64, u64, usize, Vec<(String, u64, u64, usize)>, Vec<u64>);

fn run(config: &str) -> Fingerprint {
    let e = parse_experiment(config).expect("config parses");
    let mut w = World::new(e.world.clone(), e.setups.clone());
    w.run_until(HORIZON + 600.0);
    assert!(
        w.recorder.len() > 50,
        "scenario barely ran: {} records",
        w.recorder.len()
    );
    (
        w.recorder.len(),
        (w.recorder.mean_latency() * 1e9) as u64,
        w.messages_sent,
        w.bytes_sent,
        w.messages_dropped,
        w.gossip_bytes_sent,
        w.duel_stats.total_duels(),
        w.region_summary()
            .into_iter()
            .map(|(name, slo, p99, n)| {
                (name, (slo * 1e9) as u64, (p99 * 1e9) as u64, n)
            })
            .collect(),
        w.credit_totals().iter().map(|c| (c * 1e6) as u64).collect(),
    )
}

/// Run twice in this process, assert identical fingerprints.
fn double_run(cfg: &str, what: &str) {
    let a = run(cfg);
    let b = run(cfg);
    assert_eq!(a, b, "{what}: same-process replay diverged");
}

#[test]
fn baseline_world_double_runs_identically() {
    double_run(&geo_smoke_config(), "baseline geo smoke");
}

#[test]
fn defended_world_double_runs_identically() {
    // Receipts, reputation books and hearsay capping all carry extra
    // per-peer state — the defense stack must not smuggle in hash-order
    // dependence.
    let cfg = with_block(&geo_smoke_config(), r#""defenses": { "enabled": true }"#);
    double_run(&cfg, "defenses on");
}

#[test]
fn observed_world_double_runs_identically() {
    // The flight recorder and metrics registry observe everything; they
    // must do so without perturbing or diverging the trace.
    let cfg = with_block(&geo_smoke_config(), r#""observability": { "enabled": true }"#);
    double_run(&cfg, "observability on");
}

#[test]
fn elastic_world_double_runs_identically() {
    // The reactive controller makes live scale decisions off windowed
    // signals — all of which must be order-deterministic state.
    let cfg = geo_smoke_config().replace(
        r#""latency_penalty": 50.0 } } }"#,
        r#""latency_penalty": 50.0 } },
           "capacity": { "policy": "reactive", "standby": 1,
                         "scale_up_util": 0.7, "scale_down_util": 0.2,
                         "cooldown": 6, "eval_every": 2,
                         "online_cost_per_hour": 1.0,
                         "standby_cost_per_hour": 0.1 } }"#,
    );
    assert!(cfg.contains("reactive"), "splice failed");
    double_run(&cfg, "reactive capacity");
}

#[test]
fn mixed_policy_churn_world_double_runs_identically() {
    // Heterogeneous policies + churn exercise join/leave paths where
    // membership maps get rebuilt — a classic place for unordered
    // iteration to leak into dispatch order.
    let cfg = r#"{
        "seed": 9, "horizon": 300,
        "system": { "duel_rate": 0.0 },
        "topology": {
            "regions": ["us", "eu"],
            "intra": { "latency": [0.002, 0.010] },
            "inter": { "latency": [0.040, 0.080] },
            "fleet": [
                { "region": "us", "count": 1, "policy": "requester_only",
                  "node": { "policy": { "latency_penalty": 20.0 } },
                  "schedule": [ {"from": 0, "to": 300,
                                 "inter_arrival": 2} ],
                  "lengths": { "output_mean": 600, "output_sigma": 0.5 } },
                { "region": "us", "count": 2, "policy": "greedy_local",
                  "node": { "policy": { "stake": 20 } } },
                { "region": "eu", "count": 2, "policy": "selective",
                  "node": { "policy": { "stake": 20 } },
                  "churn": [ { "at": 100, "action": "leave" },
                             { "at": 200, "action": "join" } ] },
                { "region": "eu", "count": 2,
                  "node": { "policy": { "stake": 20,
                                        "accept_freq": 1.0 } } }
            ]
        }
    }"#;
    let go = || {
        let e = parse_experiment(cfg).expect("config parses");
        let mut w = World::new(e.world.clone(), e.setups.clone());
        w.run_until(900.0);
        assert!(w.recorder.len() > 20, "churn scenario barely ran");
        (
            w.recorder.len(),
            (w.recorder.mean_latency() * 1e9) as u64,
            w.messages_sent,
            w.bytes_sent,
            w.credit_totals()
                .iter()
                .map(|c| (c * 1e6) as u64)
                .collect::<Vec<u64>>(),
        )
    };
    assert_eq!(go(), go(), "mixed-policy churn world diverged in-process");
}
