//! Same-tape ordering oracle: the calendar queue vs the seed's heap.
//!
//! The replay contract says swapping `World`'s central event queue is
//! only safe if the new structure pops in *exactly* the old order. This
//! test replays recorded push/pop tapes against both implementations —
//! [`wwwserve::sim::queue::EventQueue`] and a reference
//! `BinaryHeap<Reverse<Queued>>` carrying the seed's comparator verbatim
//! — and asserts bit-identical pop sequences: same times, same payloads,
//! same everything.
//!
//! The tapes are adversarial for a calendar queue: same-bucket bursts,
//! past-time pushes behind the cursor, far-future entries that must park
//! in the overflow heap and migrate back, times on exact bucket
//! boundaries, and `+∞`. The tie rule under test: equal-`(t, seq)` keys
//! cannot exist (seq is strictly increasing per push), so simultaneous
//! events pop in push order.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use wwwserve::sim::queue::EventQueue;
use wwwserve::util::rng::Rng;

/// The seed's queue entry and comparator, reproduced verbatim as the
/// ordering oracle.
struct Queued {
    t: f64,
    seq: u64,
    item: u32,
}

impl PartialEq for Queued {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for Queued {}
impl PartialOrd for Queued {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Queued {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t
            .partial_cmp(&other.t)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(self.seq.cmp(&other.seq))
    }
}

/// Reference implementation: the seed's `BinaryHeap<Reverse<Queued>>`
/// with its own push counter (assigned in the same push order as the
/// calendar queue's internal counter).
#[derive(Default)]
struct HeapQueue {
    heap: BinaryHeap<Reverse<Queued>>,
    seq: u64,
}

impl HeapQueue {
    fn push(&mut self, t: f64, item: u32) {
        self.seq += 1;
        self.heap.push(Reverse(Queued { t, seq: self.seq, item }));
    }

    fn pop(&mut self) -> Option<(f64, u32)> {
        self.heap.pop().map(|Reverse(q)| (q.t, q.item))
    }
}

/// One tape step: schedule at `t`, or pop.
enum Op {
    Push(f64),
    Pop,
}

/// Replay `tape` against both queues, asserting every pop agrees. Pushed
/// payloads are the tape position, so a mismatch names the exact step.
fn run_tape(tape: &[Op]) {
    let mut wheel: EventQueue<u32> = EventQueue::new();
    let mut heap = HeapQueue::default();
    for (i, op) in tape.iter().enumerate() {
        match *op {
            Op::Push(t) => {
                wheel.push(t, i as u32);
                heap.push(t, i as u32);
            }
            Op::Pop => {
                let w = wheel.pop();
                let h = heap.pop();
                match (w, h) {
                    (Some((wt, wv)), Some((ht, hv))) => {
                        assert!(
                            wt.to_bits() == ht.to_bits() && wv == hv,
                            "step {i}: wheel popped ({wt}, {wv}), \
                             heap popped ({ht}, {hv})"
                        );
                    }
                    (None, None) => {}
                    (w, h) => {
                        panic!("step {i}: wheel {w:?} vs heap {h:?}")
                    }
                }
            }
        }
    }
    // Drain both to the end: residual order must agree too.
    loop {
        match (wheel.pop(), heap.pop()) {
            (Some((wt, wv)), Some((ht, hv))) => {
                assert!(
                    wt.to_bits() == ht.to_bits() && wv == hv,
                    "drain: wheel ({wt}, {wv}) vs heap ({ht}, {hv})"
                );
            }
            (None, None) => break,
            (w, h) => panic!("drain: wheel {w:?} vs heap {h:?}"),
        }
    }
    assert!(wheel.is_empty());
}

#[test]
fn randomized_tapes_match_heap_order() {
    for seed in 0..10u64 {
        let mut rng = Rng::new(0xE0E0 + seed);
        let mut tape = Vec::new();
        let mut frontier = 0.0f64; // roughly tracks "now"
        for _ in 0..4000 {
            if rng.chance(0.55) {
                // Mixed horizons: mostly near-term, some same-instant
                // bursts, a tail of far-future entries that exercise the
                // overflow heap.
                let t = match rng.below(10) {
                    0..=5 => frontier + rng.range_f64(0.0, 2.0),
                    6 | 7 => frontier, // simultaneous: seq tiebreak
                    8 => frontier + rng.range_f64(100.0, 1500.0),
                    _ => rng.range_f64(0.0, 5000.0),
                };
                tape.push(Op::Push(t));
            } else {
                tape.push(Op::Pop);
                frontier += 0.37;
            }
        }
        run_tape(&tape);
    }
}

#[test]
fn world_shaped_tape_matches_heap_order() {
    // The shape World::new actually produces: the whole arrival trace
    // pushed up front (far beyond the ring horizon), then an interleaved
    // pop/push loop of ticks and short-latency messages.
    let mut rng = Rng::new(77);
    let mut tape = Vec::new();
    for _ in 0..2000 {
        tape.push(Op::Push(rng.range_f64(0.0, 750.0)));
    }
    let mut now = 0.0;
    for _ in 0..3000 {
        tape.push(Op::Pop);
        now += 0.25;
        if rng.chance(0.8) {
            tape.push(Op::Push(now + rng.range_f64(0.0005, 0.125)));
        }
        if rng.chance(0.3) {
            tape.push(Op::Push(now + 1.0)); // tick reschedule
        }
    }
    run_tape(&tape);
}

#[test]
fn adversarial_edges_match_heap_order() {
    let mut tape = vec![
        Op::Push(10.0),
        Op::Pop,
        // Past-time pushes behind the cursor (the heap pops them first;
        // the wheel must clamp them into the current bucket).
        Op::Push(1.0),
        Op::Push(0.0),
        Op::Push(9.999),
        Op::Pop,
        Op::Pop,
        // Exact bucket boundaries (multiples of the 0.05 s bucket width)
        // interleaved with epsilon offsets on both sides.
        Op::Push(10.05),
        Op::Push(10.049_999_999),
        Op::Push(10.050_000_001),
        Op::Push(10.10),
        Op::Pop,
        Op::Pop,
        Op::Pop,
        // Infinity parks behind all finite work, FIFO among itself.
        Op::Push(f64::INFINITY),
        Op::Push(f64::INFINITY),
        Op::Push(11.0),
        Op::Pop,
        Op::Pop,
        Op::Pop,
    ];
    // Same-bucket burst: hundreds of entries in one 0.05 s bucket.
    for i in 0..300 {
        tape.push(Op::Push(20.0 + (i % 7) as f64 * 1e-4));
    }
    for _ in 0..300 {
        tape.push(Op::Pop);
    }
    run_tape(&tape);
}

#[test]
fn pop_from_empty_agrees() {
    run_tape(&[Op::Pop, Op::Push(1.0), Op::Pop, Op::Pop, Op::Pop]);
}
