//! Failure injection: crashes, byzantine ledger behaviour, and judge loss.
//! The network must degrade gracefully — no lost user requests, no forged
//! credits.

use wwwserve::backend::Profile;
use wwwserve::crypto::{KeyStore, NodeKey};
use wwwserve::coordinator::{Event, LedgerManager, Message, Node};
use wwwserve::gossip::GossipConfig;
use wwwserve::ledger::{Block, CreditOp, OpReason, SharedLedger};
use wwwserve::policy::{NodePolicy, SystemPolicy};
use wwwserve::reputation::DefenseConfig;
use wwwserve::sim::{LedgerMode, NodeSetup, World, WorldConfig};
use wwwserve::streaming::StreamingConfig;
use wwwserve::workload::{Generator, LengthDist, Phase};
use wwwserve::NodeId;
use std::sync::{Arc, Mutex};

fn lengths() -> LengthDist {
    LengthDist { output_mean: 1000.0, output_sigma: 0.5, ..Default::default() }
}

/// An executor crashing mid-request: the originator's response timeout
/// fires and the request still completes (local fallback).
#[test]
fn executor_crash_falls_back_locally() {
    let mut setups = vec![
        // Node 0: offloads everything it can.
        NodeSetup::new(
            Profile::test(30.0, 8),
            NodePolicy {
                target_utilization: 0.0,
                offload_freq: 1.0,
                accept_freq: 1.0,
                ..Default::default()
            },
        )
        .with_generator(
            Generator::new(NodeId(0), vec![Phase::new(0.0, 120.0, 4.0)])
                .with_lengths(lengths()),
        ),
        // Node 1: the only peer — it will crash at t=60 without goodbye.
        NodeSetup::new(
            Profile::test(30.0, 8),
            NodePolicy { accept_freq: 1.0, ..Default::default() },
        ),
    ];
    setups[1].policy.stake = 10_000_000;
    let cfg = WorldConfig {
        seed: 3,
        system: SystemPolicy { duel_rate: 0.0, ..Default::default() },
        ..Default::default()
    };
    let mut w = World::new(cfg, setups);
    // Hard crash: no goodbye gossip (Leave would announce; we emulate a
    // crash by flipping the node offline directly).
    w.node_mut(1).online = false;
    // Note: node 1 never served anything from t=0, so every delegated
    // request must eventually time out and complete locally on node 0.
    w.run_until(6000.0);
    let submitted = w.node(0).stats.user_requests;
    let completed = w.recorder.user_records().count() as u64;
    assert_eq!(
        completed, submitted,
        "requests lost after executor crash ({completed}/{submitted})"
    );
    // All completions ended up on node 0 (the survivor).
    for r in w.recorder.user_records() {
        assert_eq!(r.executor, NodeId(0));
    }
    assert!(w.node(0).stats.fallback_local > 0, "no fallback happened");
}

// ---- streaming churn NACK ---------------------------------------------------

/// Two-node world for the churn-NACK regression pair: node 0 offloads
/// everything to node 1 (the only executor), which leaves honestly at
/// t = 60 while still holding delegated work. The request trace stops at
/// t = 40 so no probe/delegate handshake straddles the departure — every
/// in-flight delegation at t = 60 is one node 1 accepted and then walked
/// away from.
fn churn_nack_world(streaming: StreamingConfig) -> World {
    let mut setups = vec![
        NodeSetup::new(
            Profile::test(30.0, 8),
            NodePolicy {
                target_utilization: 0.0,
                offload_freq: 1.0,
                accept_freq: 1.0,
                ..Default::default()
            },
        )
        .with_generator(
            Generator::new(NodeId(0), vec![Phase::new(0.0, 40.0, 4.0)])
                .with_lengths(lengths()),
        ),
        NodeSetup::new(
            Profile::test(30.0, 8),
            NodePolicy { accept_freq: 1.0, ..Default::default() },
        ),
    ];
    setups[1].policy.stake = 10_000_000;
    let cfg = WorldConfig {
        seed: 21,
        system: SystemPolicy { duel_rate: 0.0, ..Default::default() },
        defenses: DefenseConfig { enabled: true, ..Default::default() },
        streaming,
        ..Default::default()
    };
    let mut w = World::new(cfg, setups);
    w.schedule_leave(1, 60.0);
    w
}

/// Minimum of node 0's effective reputation for node 1, sampled every 10 s
/// from the leave until `until`. Reputation heals with silence
/// (~0.002/s), so a timeout strike is only visible near the moment it is
/// filed — a single end-of-run readout would miss it.
fn min_effective_for_leaver(w: &mut World, until: f64) -> f64 {
    let mut min_eff = f64::INFINITY;
    let mut t = 60.0;
    while t <= until {
        w.run_until(t);
        let eff = w.node(0).defense_state().rep.effective(NodeId(1), t);
        min_eff = min_eff.min(eff);
        t += 10.0;
    }
    min_eff
}

/// [streaming] An honest leaver NACKs the delegations it still holds:
/// the origin falls back locally at once and never files a
/// Byzantine-grade `Timeout` strike against a peer that said goodbye.
#[test]
fn honest_leave_nacks_delegations_without_reputation_strike() {
    let mut w = churn_nack_world(StreamingConfig {
        enabled: true,
        ..Default::default()
    });
    let min_eff = min_effective_for_leaver(&mut w, 2000.0);
    w.run_until(6000.0);
    let submitted = w.node(0).stats.user_requests;
    let completed = w.recorder.user_records().count() as u64;
    assert_eq!(
        completed, submitted,
        "requests lost after honest leave ({completed}/{submitted})"
    );
    assert!(
        w.node(0).stats.exec_aborts > 0,
        "leaver held delegations but never NACK'd them"
    );
    assert!(
        min_eff >= 1.0,
        "honest leaver was reputation-struck despite the churn NACK \
         (min effective {min_eff})"
    );
}

/// The silent failure the NACK fixes: with streaming off, the same honest
/// departure leaves the origin waiting out the full response timeout, and
/// the leaver eats an undeserved `Timeout` reputation strike.
#[test]
fn without_churn_nack_honest_leaver_is_struck_on_timeout() {
    let mut w = churn_nack_world(StreamingConfig::default());
    let min_eff = min_effective_for_leaver(&mut w, 2000.0);
    w.run_until(6000.0);
    let submitted = w.node(0).stats.user_requests;
    let completed = w.recorder.user_records().count() as u64;
    assert_eq!(
        completed, submitted,
        "requests lost after honest leave ({completed}/{submitted})"
    );
    assert_eq!(
        w.node(0).stats.exec_aborts, 0,
        "NACKs emitted with streaming disabled"
    );
    assert!(
        w.node(0).stats.fallback_local > 0,
        "abandoned delegations never fell back"
    );
    assert!(
        min_eff < 1.0,
        "expected the pre-fix timeout strike against the honest leaver \
         (min effective {min_eff})"
    );
}

/// Mass churn: half the network leaves mid-run, everything still completes.
#[test]
fn mass_departure_keeps_service_alive() {
    let mut setups: Vec<NodeSetup> = (0..6)
        .map(|i| {
            NodeSetup::new(
                Profile::test(40.0, 16),
                NodePolicy { accept_freq: 1.0, ..Default::default() },
            )
            .with_generator(
                Generator::new(
                    NodeId(i as u32),
                    // Only the first three nodes receive user requests.
                    if i < 3 {
                        vec![Phase::new(0.0, 300.0, 5.0)]
                    } else {
                        vec![]
                    },
                )
                .with_lengths(lengths()),
            )
        })
        .collect();
    setups.truncate(6);
    let mut w = World::new(WorldConfig { seed: 9, ..Default::default() }, setups);
    w.schedule_leave(3, 100.0);
    w.schedule_leave(4, 120.0);
    w.schedule_leave(5, 140.0);
    w.run_until(6000.0);
    let submitted: u64 = (0..3).map(|i| w.node(i).stats.user_requests).sum();
    let completed = w.recorder.user_records().count() as u64;
    assert_eq!(completed, submitted, "requests lost under churn");
}

/// A forged block (bad signature / inflated mint) is rejected by every
/// honest replica.
#[test]
fn byzantine_block_rejected_by_replicas() {
    let keys = KeyStore::for_network(1, 3);
    let shared = |_: ()| ();
    let _ = shared;
    let mut honest = LedgerManager::chain(NodeKey::derive(1, NodeId(1)), keys.clone(), 2);
    // Give the honest replica some state.
    honest.submit(
        vec![CreditOp::Mint {
            to: NodeId(1),
            amount: 100,
            reason: OpReason::Genesis,
        }],
        NodeId(1),
        &[],
        0.0,
    );
    let before = honest.balance(NodeId(0));

    // Attacker forges a block claiming to be node 2 (whose key it lacks).
    let attacker_key = NodeKey::derive(99, NodeId(0)); // wrong network seed
    let head = match &honest {
        LedgerManager::Chain(r) => r.chain.head(),
        _ => unreachable!(),
    };
    let mut forged = Block::create(
        head,
        1.0,
        vec![CreditOp::Mint {
            to: NodeId(0),
            amount: 1_000_000_000,
            reason: OpReason::Genesis,
        }],
        &attacker_key,
    );
    forged.proposer = NodeId(2);

    // Replica must vote reject on the proposal and ignore the commit.
    let actions = honest.on_message(
        NodeId(0),
        &Message::BlockProposal { block: forged.clone() },
        NodeId(1),
        &[],
        1.0,
    );
    let voted_reject = actions.iter().any(|a| {
        matches!(
            a,
            wwwserve::coordinator::Action::Send {
                msg: Message::BlockVote { accept: false, .. },
                ..
            }
        )
    });
    assert!(voted_reject, "forged proposal was not rejected");
    honest.on_message(
        NodeId(0),
        &Message::BlockCommit { block: forged },
        NodeId(1),
        &[],
        1.1,
    );
    assert_eq!(
        honest.balance(NodeId(0)),
        before,
        "forged commit changed balances"
    );
}

/// A node that lies in gossip about *us* being offline cannot poison our
/// self-view, and the lie is outweighed by our own heartbeats.
#[test]
fn gossip_spoofing_self_entry_ineffective() {
    let shared = Arc::new(Mutex::new(SharedLedger::new()));
    let mut node = Node::new(
        NodeId(0),
        NodePolicy::default(),
        SystemPolicy::default(),
        Box::new(wwwserve::backend::SimBackend::new(Profile::test(10.0, 4))),
        LedgerManager::shared(shared),
        GossipConfig::default(),
        1,
        0.0,
    );
    let spoof: wwwserve::gossip::Digest = vec![(NodeId(0), 9999, false, 0, 0)];
    node.handle(
        Event::Message { from: NodeId(5), msg: Message::Gossip { digest: spoof } },
        1.0,
    );
    let e = node.view.entry(NodeId(0)).unwrap();
    assert!(e.online, "self entry was poisoned by spoofed gossip");
}

/// Duels whose judges die mid-evaluation are abandoned without corrupting
/// credit state (conservation holds throughout).
#[test]
fn judge_loss_leaves_ledger_consistent() {
    let mut setups = vec![NodeSetup::new(
        Profile::test(1.0, 1),
        NodePolicy::requester_only(),
    )
    .with_generator(
        Generator::new(NodeId(0), vec![Phase::new(0.0, 200.0, 2.0)])
            .with_lengths(lengths()),
    )];
    for _ in 0..4 {
        setups.push(NodeSetup::new(
            Profile::test(50.0, 16),
            NodePolicy { accept_freq: 1.0, ..Default::default() },
        ));
    }
    let cfg = WorldConfig {
        seed: 17,
        system: SystemPolicy { duel_rate: 0.8, ..Default::default() },
        ledger: LedgerMode::Shared,
        ..Default::default()
    };
    let mut w = World::new(cfg, setups);
    // Kill two serving nodes mid-run — in-flight duels lose executors or
    // judges.
    w.schedule_leave(3, 60.0);
    w.schedule_leave(4, 90.0);
    // Long drain: requests that fall back to the requester's own (very
    // slow) backend after executor/judge death take ~1000 s each.
    w.run_until(40_000.0);
    let ledger = w.shared_ledger().unwrap();
    let l = ledger.lock().unwrap();
    assert!(l.table().conserved(), "credit conservation broken");
    let submitted = w.node(0).stats.user_requests;
    let completed = w.recorder.user_records().count() as u64;
    assert_eq!(completed, submitted, "requests lost with dying judges");
}
