//! Integration tests for the geo-distributed WAN topology subsystem:
//! partition/heal membership dynamics, deterministic replay under link
//! events, locality-aware dispatch, and the declarative config path.

use wwwserve::backend::Profile;
use wwwserve::config::parse_experiment;
use wwwserve::policy::NodePolicy;
use wwwserve::sim::{NodeSetup, World, WorldConfig};
use wwwserve::topology::{three_region_wan, LinkChange, LinkProfile, Topology};
use wwwserve::types::ExecKind;
use wwwserve::util::rng::Rng;
use wwwserve::workload::{Generator, LengthDist, Phase};
use wwwserve::NodeId;

fn lengths() -> LengthDist {
    LengthDist { output_mean: 900.0, output_sigma: 0.5, ..Default::default() }
}

/// 2 regions x 2 nodes, no user workload: pure membership dynamics.
fn split_world(heal: bool) -> World {
    let mut b = Topology::builder()
        .region("west")
        .region("east")
        .default_intra(LinkProfile::new(0.001, 0.004))
        .link("west", "east", LinkProfile::new(0.040, 0.060))
        .nodes("west", 2)
        .nodes("east", 2)
        .event("west", "east", 50.0, LinkChange::Partition);
    if heal {
        b = b.event("west", "east", 120.0, LinkChange::Heal);
    }
    let setups = (0..4)
        .map(|_| {
            NodeSetup::new(
                Profile::test(40.0, 16),
                NodePolicy { accept_freq: 1.0, ..Default::default() },
            )
        })
        .collect();
    World::new(
        WorldConfig { seed: 42, topology: Some(b.build()), ..Default::default() },
        setups,
    )
}

/// Satellite: peers across a partitioned link time out of the gossip view,
/// drop out of the regular gossip fan-out, and rejoin after the heal.
#[test]
fn partitioned_peers_age_out_and_rejoin_after_heal() {
    let mut w = split_world(true);
    w.run_until(110.0);
    let now = w.now();
    // Cross-region heartbeats stopped at t=50: everyone suspects the far
    // side dead, while intra-region liveness is untouched.
    for (a, b) in [(0usize, 2u32), (0, 3), (1, 2), (1, 3), (2, 0), (3, 1)] {
        assert!(
            !w.node(a).view.is_alive(NodeId(b), now),
            "n{a} still sees n{b} across the partition"
        );
    }
    assert!(w.node(0).view.is_alive(NodeId(1), now));
    assert!(w.node(2).view.is_alive(NodeId(3), now));
    // The regular (alive-pool) gossip fan-out is intra-region only; a
    // cross-region peer can appear at most as the trailing suspicion probe
    // that exists to detect heals.
    let mut rng = Rng::new(1);
    for _ in 0..100 {
        let t = w.node(0).view.pick_targets(&mut rng, now);
        assert!(!t.is_empty());
        assert_eq!(t[0], NodeId(1), "alive fan-out must be intra-region");
    }
    assert!(w.messages_dropped > 0, "partition dropped no traffic");

    // After the heal, suspicion probes pull the far side back in and the
    // epidemic resumes: both sides re-admit each other.
    w.run_until(300.0);
    let now = w.now();
    for (a, b) in [(0usize, 2u32), (0, 3), (2, 0), (3, 1), (1, 2)] {
        assert!(
            w.node(a).view.is_alive(NodeId(b), now),
            "n{a} did not re-admit n{b} after heal"
        );
    }
}

/// Without a heal the far side stays dead forever (no false resurrection).
#[test]
fn unhealed_partition_stays_split() {
    let mut w = split_world(false);
    w.run_until(400.0);
    let now = w.now();
    assert!(!w.node(0).view.is_alive(NodeId(2), now));
    assert!(!w.node(2).view.is_alive(NodeId(0), now));
    assert!(w.node(0).view.is_alive(NodeId(1), now));
}

/// Satellite: two runs with the same seed and the same topology +
/// LinkEvent schedule must produce identical credit totals and recorder
/// stats; a different seed must not.
#[test]
fn deterministic_replay_with_topology_and_link_events() {
    let fingerprint = |seed: u64| {
        let topo = three_region_wan(2)
            .event("us", "asia", 100.0, LinkChange::Partition)
            .event("us", "asia", 200.0, LinkChange::Heal)
            .event(
                "us",
                "eu",
                150.0,
                LinkChange::Degrade {
                    latency_factor: 4.0,
                    bandwidth_factor: 0.25,
                },
            )
            .build();
        let setups: Vec<NodeSetup> = (0..6)
            .map(|i| {
                NodeSetup::new(
                    Profile::test(40.0, 16),
                    NodePolicy {
                        accept_freq: 1.0,
                        latency_penalty: 10.0,
                        ..Default::default()
                    },
                )
                .with_generator(
                    Generator::new(
                        NodeId(i as u32),
                        vec![Phase::new(0.0, 250.0, 5.0)],
                    )
                    .with_lengths(lengths()),
                )
            })
            .collect();
        let cfg = WorldConfig {
            seed,
            topology: Some(topo),
            ..Default::default()
        };
        let mut w = World::new(cfg, setups);
        w.run_until(1500.0);
        (
            w.recorder.len(),
            (w.recorder.mean_latency() * 1e9) as u64,
            w.messages_sent,
            w.messages_dropped,
            w.credit_totals()
                .iter()
                .map(|c| (c * 1e6) as u64)
                .collect::<Vec<_>>(),
        )
    };
    let a = fingerprint(7);
    assert!(a.0 > 50, "workload barely ran: {} records", a.0);
    assert!(a.3 > 0, "partition dropped nothing");
    assert_eq!(a, fingerprint(7), "same seed+schedule must replay exactly");
    assert_ne!(fingerprint(7), fingerprint(8));
}

/// Locality-aware dispatch keeps delegations near: with a latency penalty,
/// a us-region requester sends a smaller share of its work across oceans.
#[test]
fn latency_penalty_reduces_cross_region_delegation() {
    let run = |penalty: f64| -> (usize, usize) {
        let topo = three_region_wan(2).build(); // nodes 0,1=us 2,3=eu 4,5=asia
        let mut setups: Vec<NodeSetup> = vec![NodeSetup::new(
            Profile::test(30.0, 8),
            NodePolicy {
                target_utilization: 0.0, // always offload
                offload_freq: 1.0,
                latency_penalty: penalty,
                ..Default::default()
            },
        )
        .with_generator(
            Generator::new(NodeId(0), vec![Phase::new(0.0, 200.0, 1.5)])
                .with_lengths(lengths()),
        )];
        for _ in 1..6 {
            setups.push(NodeSetup::new(
                Profile::test(40.0, 16),
                NodePolicy { accept_freq: 1.0, ..Default::default() },
            ));
        }
        let mut cfg = WorldConfig {
            seed: 13,
            topology: Some(topo),
            ..Default::default()
        };
        cfg.system.duel_rate = 0.0;
        let mut w = World::new(cfg, setups);
        w.run_until(1500.0);
        let delegated = w
            .recorder
            .user_records()
            .filter(|r| r.kind == ExecKind::Delegated)
            .count();
        let cross = w
            .recorder
            .user_records()
            .filter(|r| r.kind == ExecKind::Delegated && r.executor.0 >= 2)
            .count();
        (delegated, cross)
    };
    let (blind_total, blind_cross) = run(0.0);
    let (aware_total, aware_cross) = run(60.0);
    assert!(blind_total > 40, "blind run barely delegated: {blind_total}");
    assert!(aware_total > 20, "aware run barely delegated: {aware_total}");
    // Region-blind sampling is stake-uniform: ~4/5 of delegations leave us.
    // With the penalty the cross share and the cross count must both drop.
    let blind_share = blind_cross as f64 / blind_total as f64;
    let aware_share = aware_cross as f64 / aware_total as f64;
    assert!(
        aware_share < blind_share - 0.1,
        "latency penalty did not localize dispatch: \
         blind {blind_cross}/{blind_total}, aware {aware_cross}/{aware_total}"
    );
}

/// The declarative config path: a parsed topology block drives a real
/// geo-distributed world end to end.
#[test]
fn config_topology_runs_end_to_end() {
    let text = r#"{
        "seed": 21,
        "horizon": 120,
        "topology": {
            "regions": ["us", "eu"],
            "intra": { "latency": [0.001, 0.004] },
            "inter": { "latency": [0.040, 0.080], "jitter": 0.003 },
            "events": [
                { "at": 40, "a": "us", "b": "eu", "change": "partition" },
                { "at": 80, "a": "us", "b": "eu", "change": "heal" }
            ]
        },
        "nodes": [
            { "region": "us", "profile": { "prefill_tok_s": 2000,
                "decode_tok_s": 40, "max_agg_decode_tok_s": 320,
                "max_batch": 16 },
              "policy": { "latency_penalty": 20.0 },
              "schedule": [ { "from": 0, "to": 100, "inter_arrival": 6 } ] },
            { "region": "us", "profile": { "prefill_tok_s": 2000,
                "decode_tok_s": 40, "max_agg_decode_tok_s": 320,
                "max_batch": 16 } },
            { "region": "eu", "profile": { "prefill_tok_s": 2000,
                "decode_tok_s": 40, "max_agg_decode_tok_s": 320,
                "max_batch": 16 } }
        ]
    }"#;
    let e = parse_experiment(text).unwrap();
    let mut w = World::new(e.world, e.setups);
    w.run_until(e.horizon + 400.0);
    let summary = w.region_summary();
    assert_eq!(summary.len(), 2);
    assert_eq!(summary[0].0, "us");
    assert_eq!(summary[1].0, "eu");
    // All load originated in us.
    assert!(summary[0].3 > 0, "us completed nothing");
    assert_eq!(summary[1].3, 0);
    assert!(w.messages_dropped > 0, "scheduled partition had no effect");
}
