//! Integration tests over the whole decentralized stack (sim fabric).

use wwwserve::backend::Profile;
use wwwserve::coordinator::LedgerManager;
use wwwserve::policy::{NodePolicy, SystemPolicy};
use wwwserve::schedulers;
use wwwserve::sim::{LedgerMode, NodeSetup, World, WorldConfig};
use wwwserve::workload::{Generator, LengthDist, Phase, Setting, SettingId};
use wwwserve::{NodeId, CREDIT};

fn lengths() -> LengthDist {
    LengthDist { output_mean: 1200.0, output_sigma: 0.5, ..Default::default() }
}

fn uniform_setups(n: usize, ia: f64, horizon: f64) -> Vec<NodeSetup> {
    (0..n)
        .map(|i| {
            NodeSetup::new(
                Profile::test(40.0, 16),
                NodePolicy { accept_freq: 1.0, ..Default::default() },
            )
            .with_generator(
                Generator::new(
                    NodeId(i as u32),
                    vec![Phase::new(0.0, horizon, ia)],
                )
                .with_lengths(lengths()),
            )
        })
        .collect()
}

/// Every submitted user request is answered exactly once.
#[test]
fn all_user_requests_complete_exactly_once() {
    let mut w =
        World::new(WorldConfig::default(), uniform_setups(4, 4.0, 300.0));
    w.run_until(4000.0);
    let submitted: u64 = (0..4).map(|i| w.node(i).stats.user_requests).sum();
    let mut ids: Vec<_> =
        w.recorder.user_records().map(|r| r.id).collect();
    assert_eq!(ids.len() as u64, submitted);
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len() as u64, submitted, "duplicate completions");
}

/// Decentralized scheduling beats single-node under a hot/cold skew and
/// stays within reach of the omniscient centralized dispatcher (Fig. 4's
/// qualitative claim, on a smaller workload than the benches).
#[test]
fn decentralized_between_single_and_centralized() {
    let horizon = 400.0;
    let profiles = vec![Profile::test(40.0, 16); 4];
    let gens = |_seed: u64| -> Vec<Option<Generator>> {
        (0..4)
            .map(|i| {
                Some(
                    Generator::new(
                        NodeId(i as u32),
                        vec![Phase::new(
                            0.0,
                            horizon,
                            if i == 0 { 1.5 } else { 20.0 },
                        )],
                    )
                    .with_lengths(lengths()),
                )
            })
            .collect()
    };
    let single =
        schedulers::run_single(profiles.clone(), gens(7), horizon, 7);
    let central =
        schedulers::run_centralized(profiles.clone(), gens(7), horizon, 7);

    let setups: Vec<NodeSetup> = profiles
        .iter()
        .zip(gens(7))
        .map(|(p, g)| {
            NodeSetup::new(
                *p,
                NodePolicy { accept_freq: 1.0, ..Default::default() },
            )
            .with_generator(g.unwrap())
        })
        .collect();
    let mut w = World::new(WorldConfig { seed: 7, ..Default::default() }, setups);
    w.run_until(horizon + 4000.0);

    let (s, c, d) = (
        single.mean_latency(),
        central.mean_latency(),
        w.recorder.mean_latency(),
    );
    assert!(d < s, "decentralized {d:.1}s should beat single {s:.1}s");
    assert!(
        d < c * 2.5,
        "decentralized {d:.1}s too far behind centralized {c:.1}s"
    );
}

/// Shared and blockchain ledger modes agree on final balances for the same
/// workload (consensus is off the request path).
#[test]
fn ledger_modes_agree_on_balances() {
    let run = |mode: LedgerMode| {
        let cfg = WorldConfig {
            seed: 5,
            ledger: mode,
            system: SystemPolicy { duel_rate: 0.0, ..Default::default() },
            ..Default::default()
        };
        let mut w = World::new(cfg, uniform_setups(4, 6.0, 200.0));
        w.run_until(3000.0);
        (w.credit_totals(), w.recorder.user_records().count())
    };
    let (shared_totals, shared_n) = run(LedgerMode::Shared);
    let (chain_totals, chain_n) = run(LedgerMode::Blockchain);
    assert_eq!(shared_n, chain_n, "request counts diverge across modes");
    // Conservation in both: offload payments only move credits around.
    let genesis_total = 4.0 * 100.0;
    let sum_s: f64 = shared_totals.iter().sum();
    let sum_c: f64 = chain_totals.iter().sum();
    assert!((sum_s - genesis_total).abs() < 1e-6);
    assert!((sum_c - genesis_total).abs() < 1e-6);
    // Identical seeds => identical economic outcomes.
    for (a, b) in shared_totals.iter().zip(&chain_totals) {
        assert!(
            (a - b).abs() < 1e-6,
            "balances diverged: {shared_totals:?} vs {chain_totals:?}"
        );
    }
}

/// Blockchain replicas converge to identical chains (anti-entropy) even
/// with a node joining late.
#[test]
fn chain_replicas_converge_with_churn() {
    let mut setups = uniform_setups(4, 6.0, 300.0);
    setups.push(NodeSetup::new(
        Profile::test(40.0, 16),
        NodePolicy { accept_freq: 1.0, ..Default::default() },
    ).offline());
    let cfg = WorldConfig {
        seed: 11,
        ledger: LedgerMode::Blockchain,
        ..Default::default()
    };
    let mut w = World::new(cfg, setups);
    w.schedule_join(4, 100.0);
    w.run_until(4000.0);
    let lens: Vec<usize> = (0..5)
        .map(|i| match w.node(i).ledger() {
            LedgerManager::Chain(r) => r.chain.len(),
            _ => 0,
        })
        .collect();
    assert!(lens[0] > 1, "no blocks were ledgered: {lens:?}");
    for l in &lens {
        assert_eq!(*l, lens[0], "replicas diverged: {lens:?}");
    }
}

/// Table-3 settings run end to end under all three strategies without
/// losing requests.
#[test]
fn settings_complete_under_all_strategies() {
    for id in [SettingId::S1, SettingId::S3] {
        let run = wwwserve::repro::run_setting(id, schedulers::Strategy::Decentralized, 3);
        assert!(run.completed > 50, "{:?} too few: {}", id, run.completed);
        let setting = Setting::get(id);
        assert!(setting.num_nodes() >= 4);
    }
}

/// Same seed ⇒ bit-identical world outcomes; different seed ⇒ different.
#[test]
fn world_determinism() {
    let run = |seed| {
        let cfg = WorldConfig { seed, ..Default::default() };
        let mut w = World::new(cfg, uniform_setups(3, 5.0, 150.0));
        w.run_until(2000.0);
        (
            (w.recorder.mean_latency() * 1e9) as u64,
            w.messages_sent,
            w.recorder.len(),
        )
    };
    assert_eq!(run(42), run(42));
    assert_ne!(run(42), run(43));
}

/// The duel mechanism redistributes credit from low- to high-quality nodes
/// over a long horizon (Theorem 5.8, agent-based).
#[test]
fn duels_redistribute_toward_quality() {
    let mut setups = vec![NodeSetup::new(
        Profile::test(1.0, 1),
        NodePolicy::requester_only(),
    )
    .with_generator(
        Generator::new(NodeId(0), vec![Phase::new(0.0, 400.0, 1.5)])
            .with_lengths(lengths()),
    )];
    for q in [0.9, 0.9, 0.3, 0.3] {
        setups.push(NodeSetup::new(
            Profile::test(50.0, 16).with_quality(q),
            NodePolicy { accept_freq: 1.0, ..Default::default() },
        ));
    }
    let cfg = WorldConfig {
        seed: 13,
        system: SystemPolicy {
            duel_rate: 0.6,
            duel_reward: CREDIT,
            duel_penalty: CREDIT,
            ..Default::default()
        },
        ..Default::default()
    };
    let mut w = World::new(cfg, setups);
    w.run_until(4000.0);
    let totals = w.credit_totals();
    let high = totals[1] + totals[2];
    let low = totals[3] + totals[4];
    assert!(
        high > low + 5.0,
        "no quality redistribution: high {high:.1} vs low {low:.1}"
    );
}
