//! Integration tests for live latency estimation: dispatch must shed a
//! partitioned (or drastically degraded) region within a few gossip
//! intervals of the event and re-admit it after the heal — and the static
//! expected-latency-matrix baseline (`latency_estimation.enabled = false`)
//! must demonstrably *not* shed it.
//!
//! Gossip liveness aging also eventually sheds fully partitioned peers, so
//! these scenarios pin `suspect_after` far beyond the outage: whatever
//! rerouting happens is the estimator's doing alone.

use wwwserve::backend::Profile;
use wwwserve::config::parse_experiment;
use wwwserve::policy::NodePolicy;
use wwwserve::sim::{NodeSetup, World, WorldConfig};
use wwwserve::topology::{three_region_wan, LinkChange, LinkProfile, Topology};
use wwwserve::types::CREDIT;
use wwwserve::workload::{Generator, LengthDist, Phase};
use wwwserve::NodeId;

fn lengths() -> LengthDist {
    LengthDist { output_mean: 900.0, output_sigma: 0.5, ..Default::default() }
}

/// One always-delegating requester plus two servers per region; node order
/// matches the contiguous region placement of the topology builders.
fn reroute_setups(regions: usize, horizon: f64) -> Vec<NodeSetup> {
    let mut setups = Vec::new();
    for region in 0..regions {
        let requester_id = NodeId((region * 3) as u32);
        setups.push(
            NodeSetup::new(
                Profile::test(40.0, 4),
                NodePolicy {
                    latency_penalty: 50.0,
                    ..NodePolicy::requester_only()
                },
            )
            .with_generator(
                Generator::new(
                    requester_id,
                    vec![Phase::new(0.0, horizon, 1.0)],
                )
                .with_lengths(lengths()),
            ),
        );
        for _ in 0..2 {
            setups.push(NodeSetup::new(
                Profile::test(45.0, 24),
                NodePolicy {
                    stake: 20 * CREDIT,
                    accept_freq: 1.0,
                    latency_penalty: 50.0,
                    ..Default::default()
                },
            ));
        }
    }
    setups
}

struct Windowed {
    pre: u64,
    part: u64,
    recovered: u64,
}

/// Run the 3-region partition scenario (us<->asia down 100s..250s) and
/// window the us<->asia dispatch sends: before the partition, after a
/// 20-gossip-interval convergence grace, and after the heal plus a
/// 60-second re-admission grace.
fn run_partition(live: bool) -> Windowed {
    const T_PART: f64 = 100.0;
    const T_CONVERGED: f64 = 120.0; // K = 20 one-second gossip intervals
    const T_HEAL: f64 = 250.0;
    const T_READMIT: f64 = 310.0;
    const HORIZON: f64 = 400.0;
    let topo = three_region_wan(3)
        .event("us", "asia", T_PART, LinkChange::Partition)
        .event("us", "asia", T_HEAL, LinkChange::Heal)
        .build();
    let mut cfg = WorldConfig { seed: 77, topology: Some(topo), ..Default::default() };
    cfg.system.duel_rate = 0.0;
    // Liveness aging must never shed the far side during the outage.
    cfg.gossip.suspect_after = 1e4;
    cfg.latency_estimation.enabled = live;
    // Penalized estimates must not decay back to the prior mid-outage.
    cfg.latency_estimation.decay_after = 500.0;
    let mut w = World::new(cfg, reroute_setups(3, HORIZON));
    let cross = |w: &World| w.dispatch_sends(0, 2) + w.dispatch_sends(2, 0);

    w.run_until(T_PART);
    let pre = cross(&w);
    w.run_until(T_CONVERGED);
    let at_converged = cross(&w);
    w.run_until(T_HEAL);
    let part = cross(&w) - at_converged;
    w.run_until(T_READMIT);
    let at_readmit = cross(&w);
    w.run_until(HORIZON);
    let recovered = cross(&w) - at_readmit;
    assert!(w.messages_dropped > 0, "partition dropped no traffic");
    Windowed { pre, part, recovered }
}

#[test]
fn partition_is_shed_within_k_intervals_and_readmitted_after_heal() {
    let live = run_partition(true);
    let frozen = run_partition(false);

    // Both runs delegate across the healthy us<->asia link beforehand.
    assert!(live.pre > 0, "live run never delegated cross-region");
    assert!(frozen.pre > 0, "baseline never delegated cross-region");

    // The static matrix keeps pouring probes into the dead link for the
    // whole outage (liveness aging is pinned off) ...
    assert!(
        frozen.part >= 10,
        "static baseline unexpectedly shed the partitioned region \
         ({} cross sends in the outage window)",
        frozen.part
    );
    // ... while the live estimator sheds it within K = 20 gossip
    // intervals: timeout penalties crush the region's selection weight.
    assert!(
        live.part <= 10,
        "live estimation kept delegating into the partition: {} sends",
        live.part
    );
    assert!(
        live.part * 3 <= frozen.part,
        "live estimation barely better than the static baseline: \
         live {} vs static {}",
        live.part,
        frozen.part
    );

    // After the heal, gossip round trips measure the recovered link and
    // dispatch re-admits the region.
    assert!(
        live.recovered > 0,
        "live estimation never re-admitted the healed region"
    );
}

/// A severe degrade (not a partition): heartbeats still flow, so liveness
/// aging never fires at any `suspect_after` — only measured latency can
/// reroute. The frozen baseline keeps its cross-region share forever.
#[test]
fn degrade_reroutes_live_dispatch_but_not_static_baseline() {
    const T_DEG: f64 = 100.0;
    const T_CONVERGED: f64 = 130.0;
    const HORIZON: f64 = 300.0;
    let run = |live: bool| -> (u64, u64) {
        let topo = Topology::builder()
            .region("west")
            .region("east")
            .default_intra(
                LinkProfile::new(0.0005, 0.002).with_bandwidth_mbps(10_000.0),
            )
            .link(
                "west",
                "east",
                LinkProfile::new(0.045, 0.055).with_bandwidth_mbps(400.0),
            )
            .nodes("west", 3)
            .nodes("east", 3)
            .event(
                "west",
                "east",
                T_DEG,
                LinkChange::Degrade {
                    latency_factor: 40.0,
                    bandwidth_factor: 1.0,
                },
            )
            .build();
        let mut cfg =
            WorldConfig { seed: 41, topology: Some(topo), ..Default::default() };
        cfg.system.duel_rate = 0.0;
        cfg.gossip.suspect_after = 1e4;
        cfg.latency_estimation.enabled = live;
        cfg.latency_estimation.decay_after = 500.0;
        let mut w = World::new(cfg, reroute_setups(2, HORIZON));
        let cross = |w: &World| w.dispatch_sends(0, 1) + w.dispatch_sends(1, 0);
        w.run_until(T_CONVERGED);
        let before = cross(&w);
        w.run_until(HORIZON);
        (before, cross(&w) - before)
    };
    let (live_pre, live_deg) = run(true);
    let (frozen_pre, frozen_deg) = run(false);
    assert!(live_pre > 0 && frozen_pre > 0, "no cross traffic at all");
    assert!(
        frozen_deg >= 15,
        "static baseline should keep delegating over the degraded link, \
         sent only {frozen_deg}"
    );
    assert!(
        live_deg * 3 <= frozen_deg,
        "live estimation failed to shed the degraded link: \
         live {live_deg} vs static {frozen_deg}"
    );
}

/// The declarative `latency_estimation` block drives a real world end to
/// end, and the frozen baseline is reachable from config.
#[test]
fn latency_estimation_config_runs_end_to_end() {
    let text = r#"{
        "seed": 5,
        "horizon": 60,
        "latency_estimation": { "alpha": 0.4, "decay_after": 45,
                                "share_every": 2 },
        "topology": {
            "regions": ["us", "eu"],
            "intra": { "latency": [0.001, 0.004] },
            "inter": { "latency": [0.040, 0.080] },
            "fleet": [
                { "region": "us", "count": 3,
                  "node": { "policy": { "accept_freq": 1.0,
                                        "latency_penalty": 20.0 } },
                  "schedule": [ { "from": 0, "to": 60,
                                  "inter_arrival": 3 } ],
                  "lengths": { "output_mean": 600, "output_sigma": 0.5 } },
                { "region": "eu", "count": 3,
                  "node": { "policy": { "accept_freq": 1.0,
                                        "latency_penalty": 20.0 } } }
            ]
        }
    }"#;
    let e = parse_experiment(text).unwrap();
    assert!((e.world.latency_estimation.alpha - 0.4).abs() < 1e-12);
    let mut w = World::new(e.world, e.setups);
    w.run_until(e.horizon + 200.0);
    // Estimators were installed and fed: at least one node's us->eu
    // estimate moved off (or validated) the prior, and the run completed
    // real work.
    assert!(w.recorder.len() > 5, "workload barely ran");
    let est = w.node(0).latency_estimator().expect("estimator installed");
    assert!(est.config().enabled);
    assert!((est.config().alpha - 0.4).abs() < 1e-12);
    assert!(est.version() > 0, "no RTT observation ever landed");
}
