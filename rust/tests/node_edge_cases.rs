//! Edge-case tests on the node protocol state machine: stale/duplicate/
//! malicious message handling that the happy-path tests never trigger.

use std::sync::{Arc, Mutex};

use wwwserve::backend::{Backend, Profile, SimBackend};
use wwwserve::coordinator::{Action, Event, LedgerManager, Message, Node};
use wwwserve::gossip::GossipConfig;
use wwwserve::ledger::{Ledger, SharedLedger};
use wwwserve::policy::{NodePolicy, SystemPolicy};
use wwwserve::types::{Request, RequestId, Response};
use wwwserve::NodeId;

fn mk_node(id: u32, shared: &Arc<Mutex<SharedLedger>>) -> Node {
    Node::new(
        NodeId(id),
        NodePolicy::default(),
        SystemPolicy::default(),
        Box::new(SimBackend::new(Profile::test(50.0, 8))),
        LedgerManager::shared(shared.clone()),
        GossipConfig::default(),
        7,
        0.0,
    )
}

fn req(origin: u32, seq: u64) -> Request {
    Request {
        id: RequestId { origin: NodeId(origin), seq },
        prompt_tokens: 50,
        output_tokens: 100,
        submitted_at: 0.0,
        slo_deadline: 60.0,
        synthetic: false,
        payload: vec![],
        session: 0,
        ttft_deadline: f64::INFINITY,
    }
}

fn resp(origin: u32, seq: u64, executor: u32) -> Response {
    Response {
        id: RequestId { origin: NodeId(origin), seq },
        executor: NodeId(executor),
        quality: 0.7,
        finished_at: 5.0,
        first_token_at: None,
        tokens: vec![],
    }
}

fn sends(actions: &[Action]) -> usize {
    actions
        .iter()
        .filter(|a| matches!(a, Action::Send { .. }))
        .count()
}

#[test]
fn unsolicited_probe_accept_is_ignored() {
    let shared = Arc::new(Mutex::new(SharedLedger::new()));
    let mut n = mk_node(0, &shared);
    let a = n.handle(
        Event::Message {
            from: NodeId(3),
            msg: Message::ProbeAccept { req_id: req(0, 99).id },
        },
        1.0,
    );
    // No delegation must be triggered by an accept we never asked for.
    assert!(!a.iter().any(
        |x| matches!(x, Action::Send { msg: Message::Delegate { .. }, .. })
    ));
}

#[test]
fn unsolicited_response_is_ignored_and_unpaid() {
    let shared = Arc::new(Mutex::new(SharedLedger::new()));
    let mut n = mk_node(0, &shared);
    let before = shared.lock().unwrap().balance(NodeId(0));
    let a = n.handle(
        Event::Message {
            from: NodeId(3),
            msg: Message::DelegateResponse {
                response: resp(0, 42, 3),
                duel: false,
                receipt: None,
            },
        },
        1.0,
    );
    assert!(!a.iter().any(|x| matches!(x, Action::Done(_))));
    // A fabricated response must not extract a payment.
    assert_eq!(shared.lock().unwrap().balance(NodeId(0)), before);
}

#[test]
fn duplicate_response_pays_only_once() {
    let shared = Arc::new(Mutex::new(SharedLedger::new()));
    let mut n1 = mk_node(1, &shared);
    let mut n0 = mk_node(0, &shared);
    n0.policy.target_utilization = 0.0;
    n0.policy.offload_freq = 1.0;
    n0.system.duel_rate = 0.0;
    n1.policy.accept_freq = 1.0;
    n0.view.merge(&[(NodeId(1), 1, true, 0, 0)], 0.0);

    // Run the probe/delegate handshake.
    let a = n0.handle(Event::UserRequest(req(0, 0)), 0.0);
    let Action::Send { msg: probe, .. } = &a[0] else { panic!() };
    let a = n1.handle(Event::Message { from: NodeId(0), msg: probe.clone() }, 0.1);
    let Action::Send { msg: accept, .. } = &a[0] else { panic!() };
    n0.handle(Event::Message { from: NodeId(1), msg: accept.clone() }, 0.2);

    let balance_before = shared.lock().unwrap().balance(NodeId(1));
    let response = Message::DelegateResponse {
        response: resp(0, 0, 1),
        duel: false,
        receipt: None,
    };
    let a1 = n0.handle(
        Event::Message { from: NodeId(1), msg: response.clone() },
        5.0,
    );
    assert!(a1.iter().any(|x| matches!(x, Action::Done(_))));
    // Replay the same response: no second payment, no second Done.
    let a2 = n0.handle(Event::Message { from: NodeId(1), msg: response }, 6.0);
    assert!(!a2.iter().any(|x| matches!(x, Action::Done(_))));
    let paid = shared.lock().unwrap().balance(NodeId(1)) - balance_before;
    assert_eq!(paid, SystemPolicy::default().base_reward);
}

#[test]
fn verdict_for_unknown_duel_is_ignored() {
    let shared = Arc::new(Mutex::new(SharedLedger::new()));
    let mut n = mk_node(0, &shared);
    let a = n.handle(
        Event::Message {
            from: NodeId(2),
            msg: Message::JudgeVerdict {
                duel_id: req(0, 77).id,
                winner: NodeId(2),
            },
        },
        1.0,
    );
    assert!(!a.iter().any(|x| matches!(x, Action::DuelSettled(_))));
    assert_eq!(sends(&a), 0);
}

#[test]
fn judge_assign_runs_even_when_busy() {
    // Judging work enters the delegated queue and eventually produces a
    // verdict even if the judge's backend is saturated.
    let shared = Arc::new(Mutex::new(SharedLedger::new()));
    let mut judge = mk_node(0, &shared);
    // Saturate the backend.
    for s in 0..20 {
        judge.handle(Event::UserRequest(req(0, s)), 0.0);
    }
    let a = judge.handle(
        Event::Message {
            from: NodeId(9),
            msg: Message::JudgeAssign {
                duel_id: req(9, 1).id,
                resp_a: resp(9, 1, 2),
                resp_b: resp(9, 1, 3),
                est_tokens: 200,
            },
        },
        1.0,
    );
    // No verdict yet (queued behind the backlog).
    assert_eq!(sends(&a), 0);
    // Run the backend far forward: the verdict must eventually emerge.
    let mut verdict_seen = false;
    let mut t = 10.0;
    for _ in 0..200 {
        for act in judge.handle(Event::BackendWake, t) {
            if let Action::Send { msg: Message::JudgeVerdict { .. }, .. } = act {
                verdict_seen = true;
            }
        }
        t += 10.0;
    }
    assert!(verdict_seen, "judge never produced a verdict");
}

#[test]
fn requester_cannot_delegate_without_funds() {
    let shared = Arc::new(Mutex::new(SharedLedger::new()));
    let mut n1 = mk_node(1, &shared);
    let mut n0 = mk_node(0, &shared);
    n0.policy.target_utilization = 0.0;
    n0.policy.offload_freq = 1.0;
    n0.system.duel_rate = 0.0;
    n1.policy.accept_freq = 1.0;
    n0.view.merge(&[(NodeId(1), 1, true, 0, 0)], 0.0);
    // Drain node 0's liquid balance (move everything into stake).
    let balance = shared.lock().unwrap().balance(NodeId(0));
    shared
        .lock()
        .unwrap()
        .submit(
            vec![wwwserve::ledger::CreditOp::Stake {
                node: NodeId(0),
                amount: balance,
            }],
            NodeId(0),
            0.0,
        )
        .unwrap();
    let a = n0.handle(Event::UserRequest(req(0, 0)), 1.0);
    // Unaffordable offload -> local execution, no probe.
    assert_eq!(sends(&a), 0);
    assert_eq!(n0.backend().running_len(), 1);
}

#[test]
fn gossip_reply_does_not_echo_forever() {
    let shared = Arc::new(Mutex::new(SharedLedger::new()));
    let mut a = mk_node(0, &shared);
    let mut b = mk_node(1, &shared);
    a.view.add_seed(NodeId(1), 0, 0, 0.0);
    b.view.add_seed(NodeId(0), 0, 0, 0.0);
    // a gossips to b; b replies; a must NOT reply to the reply.
    let out_a = a.handle(Event::Tick, 1.0);
    let gossip = out_a.iter().find_map(|x| match x {
        Action::Send { msg: m @ Message::Gossip { .. }, .. } => Some(m.clone()),
        _ => None,
    });
    let Some(gossip) = gossip else {
        panic!("no gossip emitted on tick")
    };
    let out_b = b.handle(Event::Message { from: NodeId(0), msg: gossip }, 1.1);
    let reply = out_b
        .iter()
        .find_map(|x| match x {
            Action::Send { msg: m @ Message::GossipReply { .. }, .. } => {
                Some(m.clone())
            }
            _ => None,
        })
        .expect("push-pull reply");
    let out_a2 = a.handle(Event::Message { from: NodeId(1), msg: reply }, 1.2);
    assert_eq!(
        sends(&out_a2),
        0,
        "gossip reply must terminate the exchange"
    );
}
