//! Causal request tracing — end-to-end hop-chain reconstruction.
//!
//! These tests exercise the flight recorder through full `World` runs on a
//! two-region WAN: a requester-only origin in `us` and two servers in `eu`,
//! so every completed user request is a *cross-region delegation*. The
//! acceptance contract:
//!
//! * the full hop chain of a delegated request — admit → probe →
//!   delegate → queue → execute → settle — is reconstructable from the
//!   stitched span trees AND from the exported Chrome trace JSON;
//! * a mid-run partition produces the timeout-and-fallback chain
//!   (admit → probe → timeout → local execute) with no settle hop;
//! * `slo_misses_only` keeps exactly the trees whose request missed its
//!   SLO (or never completed), and nothing else.

use wwwserve::config::parse_experiment;
use wwwserve::obs::SpanKind;
use wwwserve::sim::World;
use wwwserve::util::json::Json;

const HORIZON: f64 = 120.0;

/// One requester in `us`, two servers in `eu`. `events` optionally
/// injects link events (e.g. a mid-run partition).
fn cross_region_config(events: &str, observability: &str) -> String {
    format!(
        r#"{{
            "seed": 42,
            "horizon": {HORIZON},
            "system": {{ "duel_rate": 0.0 }},
            "observability": {observability},
            "topology": {{
                "regions": ["us", "eu"],
                "intra": {{ "latency": [0.002, 0.010] }},
                "inter": {{ "latency": [0.040, 0.080], "jitter": 0.005 }},
                {events}
                "fleet": [
                    {{ "region": "us", "count": 1,
                       "policy": "requester_only",
                       "node": {{
                         "profile": {{ "prefill_tok_s": 2000,
                                       "decode_tok_s": 40,
                                       "max_agg_decode_tok_s": 160,
                                       "max_batch": 4 }} }},
                       "schedule": [ {{"from": 10, "to": {HORIZON},
                                       "inter_arrival": 4}} ],
                       "lengths": {{ "output_mean": 600,
                                     "output_sigma": 0.5 }} }},
                    {{ "region": "eu", "count": 2,
                       "node": {{
                         "profile": {{ "prefill_tok_s": 4000,
                                       "decode_tok_s": 45,
                                       "max_agg_decode_tok_s": 1080,
                                       "max_batch": 24 }},
                         "policy": {{ "stake": 20,
                                      "accept_freq": 1.0 }} }} }}
                ]
            }}
        }}"#
    )
}

fn run(config: &str) -> World {
    let e = parse_experiment(config).expect("config parses");
    let mut w = World::new(e.world.clone(), e.setups.clone());
    w.run_until(HORIZON + 300.0);
    assert!(
        w.recorder.user_records().count() > 10,
        "scenario barely ran: {} user records",
        w.recorder.user_records().count()
    );
    w
}

/// The canonical happy-path hop chain of a cross-region delegation.
const HAPPY_CHAIN: [SpanKind; 8] = [
    SpanKind::Admit,
    SpanKind::ProbeSent,
    SpanKind::ProbeAcked,
    SpanKind::Delegate,
    SpanKind::Queue,
    SpanKind::ExecuteStart,
    SpanKind::ExecuteEnd,
    SpanKind::Settle,
];

#[test]
fn reconstructs_cross_region_delegation_hop_chain() {
    let w = run(&cross_region_config("", r#"{ "enabled": true }"#));
    let trees = w.span_trees();
    assert!(!trees.is_empty(), "no span trees recorded");

    // At least one request walked the textbook chain with no retries.
    let tree = trees
        .iter()
        .find(|t| t.kinds() == HAPPY_CHAIN)
        .unwrap_or_else(|| {
            panic!(
                "no tree matches the canonical chain; saw e.g. {:?}",
                trees.first().map(|t| t.kinds())
            )
        });

    // The chain really crosses the region boundary: admit/settle on the
    // us requester (node 0), queue/execute on a eu server (node 1 or 2).
    let origin = tree.spans[0].node;
    assert_eq!(origin.0, 0, "requests originate at the requester");
    for s in &tree.spans {
        match s.kind {
            SpanKind::Admit
            | SpanKind::ProbeSent
            | SpanKind::ProbeAcked
            | SpanKind::Delegate
            | SpanKind::Settle => assert_eq!(s.node, origin),
            SpanKind::Queue
            | SpanKind::ExecuteStart
            | SpanKind::ExecuteEnd => {
                assert_ne!(s.node, origin, "{:?} ran at the origin", s.kind)
            }
            other => panic!("unexpected span {other:?}"),
        }
    }
    let executor = tree.spans[4].node;
    assert!(executor.0 == 1 || executor.0 == 2, "executor {executor}");

    // Causal order: time is monotone along the chain.
    for pair in tree.spans.windows(2) {
        assert!(pair[0].t <= pair[1].t, "span times went backwards");
    }

    // The recorder agrees about who executed it.
    let rec = w
        .recorder
        .user_records()
        .find(|r| r.id == tree.req)
        .expect("traced request has a record");
    assert_eq!(rec.executor, executor);
    assert_eq!(rec.origin, origin);

    // And the same chain is reconstructable from the exported Chrome
    // trace JSON alone — filter the instant events of this request; the
    // export preserves tree order.
    let doc = w.trace_json();
    let reparsed =
        Json::parse(&format!("{doc}")).expect("export is valid JSON");
    let events = reparsed
        .get("traceEvents")
        .as_arr()
        .expect("traceEvents array");
    let req_str = format!("{}", tree.req);
    let names: Vec<String> = events
        .iter()
        .filter(|e| {
            e.get("ph").as_str() == Some("i")
                && e.get("args").get("req").as_str() == Some(&req_str)
        })
        .map(|e| e.get("name").as_str().unwrap().to_string())
        .collect();
    assert_eq!(
        names,
        vec![
            "admit",
            "probe_sent",
            "probe_acked",
            "delegate",
            "queue",
            "execute_start",
            "execute_end",
            "settle"
        ]
    );
    // The executor's execute_start/execute_end pair became a duration
    // slice attributed to the executor's process row.
    let slice = events
        .iter()
        .find(|e| {
            e.get("ph").as_str() == Some("X")
                && e.get("args").get("req").as_str() == Some(&req_str)
        })
        .expect("execute slice exported");
    assert_eq!(slice.get("pid").as_f64(), Some(executor.0 as f64));
    assert!(slice.get("dur").as_f64().unwrap() > 0.0);
}

#[test]
fn partition_produces_timeout_and_fallback_chain() {
    // Cut us<->eu mid-run: probes in flight (or sent during the outage)
    // die, the origin times out and serves locally.
    let events = r#""events": [
        { "at": 30, "a": "us", "b": "eu", "change": "partition" },
        { "at": 90, "a": "us", "b": "eu", "change": "heal" }
    ],"#;
    let w = run(&cross_region_config(events, r#"{ "enabled": true }"#));
    let trees = w.span_trees();

    let tree = trees
        .iter()
        .find(|t| {
            let k = t.kinds();
            k.contains(&SpanKind::Timeout)
                && k.contains(&SpanKind::ExecuteStart)
                && k.contains(&SpanKind::ExecuteEnd)
                && !k.contains(&SpanKind::Settle)
                && !k.contains(&SpanKind::Delegate)
        })
        .expect("no timeout-and-fallback tree recorded");
    let k = tree.kinds();
    assert_eq!(k[0], SpanKind::Admit);
    assert!(k.contains(&SpanKind::ProbeSent), "fallback without a probe");
    // The whole chain stays on the origin — nothing ever left us.
    assert!(tree.spans.iter().all(|s| s.node.0 == 0));
    // The timeout fired while still probing (detail 0 = Probing state).
    let to = tree
        .spans
        .iter()
        .find(|s| s.kind == SpanKind::Timeout)
        .unwrap();
    assert_eq!(to.detail, 0, "expected a probe-phase timeout");
    // And the timeout precedes the local execution it triggered.
    let t_exec = tree
        .spans
        .iter()
        .find(|s| s.kind == SpanKind::ExecuteStart)
        .unwrap()
        .t;
    assert!(to.t <= t_exec, "timeout after the fallback execution");
}

#[test]
fn slo_misses_only_keeps_exactly_the_violating_traces() {
    // The partition scenario yields a mix of met and missed SLOs. Both
    // runs are the same simulation (tracing is observational; the flag
    // only filters at export), so the full run predicts exactly which
    // trees the misses-only run must keep.
    let events = r#""events": [
        { "at": 30, "a": "us", "b": "eu", "change": "partition" },
        { "at": 90, "a": "us", "b": "eu", "change": "heal" }
    ],"#;
    let full = run(&cross_region_config(events, r#"{ "enabled": true }"#));
    let misses = run(&cross_region_config(
        events,
        r#"{ "enabled": true, "slo_misses_only": true }"#,
    ));

    let slo_met = |w: &World, req| {
        w.recorder
            .user_records()
            .find(|r| r.id == req)
            .map(|r| r.slo_met())
    };
    let expected: Vec<_> = full
        .span_trees()
        .into_iter()
        .map(|t| t.req)
        .filter(|req| !slo_met(&full, *req).unwrap_or(false))
        .collect();
    let kept: Vec<_> =
        misses.span_trees().into_iter().map(|t| t.req).collect();
    assert_eq!(kept, expected, "filter kept the wrong trace set");
    assert!(!kept.is_empty(), "partition scenario produced no SLO misses");
    assert!(
        kept.len() < full.span_trees().len(),
        "every request missed its SLO — filter untestable"
    );
    // Every kept tree is a genuine violation (or never completed).
    for req in &kept {
        assert_ne!(
            slo_met(&misses, *req),
            Some(true),
            "{req} met its SLO but was kept"
        );
    }
}
