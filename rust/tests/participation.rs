//! End-to-end behaviour of the pluggable participation policies and the
//! declarative fleet churn schedules.

use std::sync::{Arc, Mutex};

use wwwserve::backend::{Profile, SimBackend};
use wwwserve::config::parse_experiment;
use wwwserve::coordinator::{Action, Event, LedgerManager, Message, Node};
use wwwserve::gossip::GossipConfig;
use wwwserve::ledger::SharedLedger;
use wwwserve::policy::{GreedyLocal, NodePolicy, SelectiveAcceptor, SystemPolicy};
use wwwserve::sim::World;
use wwwserve::types::{Request, RequestId};
use wwwserve::NodeId;

fn mk_node(id: u32, policy: NodePolicy, shared: &Arc<Mutex<SharedLedger>>) -> Node {
    Node::new(
        NodeId(id),
        policy,
        SystemPolicy::default(),
        Box::new(SimBackend::new(Profile::test(50.0, 4))),
        LedgerManager::shared(shared.clone()),
        GossipConfig::default(),
        42,
        0.0,
    )
}

fn user_req(origin: u32, seq: u64, now: f64) -> Request {
    Request {
        id: RequestId { origin: NodeId(origin), seq },
        prompt_tokens: 100,
        output_tokens: 100,
        submitted_at: now,
        slo_deadline: 60.0,
        synthetic: false,
        payload: vec![],
        session: 0,
        ttft_deadline: f64::INFINITY,
    }
}

#[test]
fn greedy_local_node_serves_own_load_accepts_delegations() {
    let shared = Arc::new(Mutex::new(SharedLedger::new()));
    let _n1 = mk_node(1, NodePolicy::default(), &shared);
    // Knobs scream "offload" — the participation object overrides.
    let mut n0 = mk_node(
        0,
        NodePolicy {
            target_utilization: 0.0,
            offload_freq: 1.0,
            accept_freq: 0.0, // greedy ignores this too
            ..Default::default()
        },
        &shared,
    );
    n0.set_participation(Box::new(GreedyLocal));
    n0.view.merge(&[(NodeId(1), 1, true, 0, 0)], 0.0);
    let a = n0.handle(Event::UserRequest(user_req(0, 0, 0.0)), 0.0);
    assert!(
        a.iter().all(|x| !matches!(x, Action::Send { .. })),
        "greedy_local must not probe: {a:?}"
    );
    assert_eq!(n0.backend().running_len(), 1);
    // An incoming probe is accepted despite accept_freq = 0.
    let a = n0.handle(
        Event::Message {
            from: NodeId(1),
            msg: Message::Probe {
                req_id: RequestId { origin: NodeId(1), seq: 9 },
                prompt_tokens: 10,
                output_tokens: 10,
            },
        },
        0.1,
    );
    assert!(a.iter().any(|x| matches!(
        x,
        Action::Send { msg: Message::ProbeAccept { .. }, .. }
    )));
}

#[test]
fn selective_acceptor_cherry_picks_short_jobs() {
    let shared = Arc::new(Mutex::new(SharedLedger::new()));
    let _n1 = mk_node(1, NodePolicy::default(), &shared);
    let mut n0 = mk_node(0, NodePolicy::default(), &shared);
    n0.set_participation(Box::new(SelectiveAcceptor {
        max_output_tokens: 200,
        max_utilization: 0.5,
    }));
    let probe = |n: &mut Node, seq: u64, out: u32| -> &'static str {
        let a = n.handle(
            Event::Message {
                from: NodeId(1),
                msg: Message::Probe {
                    req_id: RequestId { origin: NodeId(1), seq },
                    prompt_tokens: 50,
                    output_tokens: out,
                },
            },
            0.1,
        );
        a.iter()
            .find_map(|x| match x {
                Action::Send { msg, .. } => Some(msg.kind()),
                _ => None,
            })
            .expect("probe answered")
    };
    // Idle node: short jobs accepted, long jobs rejected.
    assert_eq!(probe(&mut n0, 0, 150), "probe_accept");
    assert_eq!(probe(&mut n0, 1, 5000), "probe_reject");
    // Busy node (own work running): even short jobs rejected.
    for seq in 0..4 {
        n0.handle(Event::UserRequest(user_req(0, 100 + seq, 0.0)), 0.0);
    }
    assert!(n0.backend().utilization() > 0.5);
    assert_eq!(probe(&mut n0, 2, 150), "probe_reject");
}

#[test]
fn fleet_churn_schedule_drives_leave_and_join() {
    // Two us servers churn out at t=60 and rejoin at t=160; a steady
    // requester keeps the world busy throughout. The gossip views must
    // reflect the outage window and the recovery.
    let cfg = r#"{
        "seed": 5, "horizon": 300,
        "system": { "duel_rate": 0.0 },
        "topology": {
            "regions": ["us"],
            "intra": { "latency": [0.002, 0.010] },
            "fleet": [
                { "region": "us", "count": 1, "policy": "requester_only",
                  "schedule": [ {"from": 0, "to": 300,
                                 "inter_arrival": 5} ],
                  "lengths": { "output_mean": 400,
                               "output_sigma": 0.5 } },
                { "region": "us", "count": 2,
                  "node": { "policy": { "stake": 20,
                                        "accept_freq": 1.0 } } },
                { "region": "us", "count": 2, "name": "churners",
                  "node": { "policy": { "stake": 20,
                                        "accept_freq": 1.0 } },
                  "churn": [ { "at": 60, "action": "leave", "count": 2 },
                             { "at": 160, "action": "join", "count": 2 } ] }
            ]
        }
    }"#;
    let e = parse_experiment(cfg).expect("config parses");
    assert_eq!(e.churn.len(), 4);
    assert_eq!(e.setups[3].group.as_deref(), Some("churners"));
    // World::new installs the schedule from world.churn — no extra call.
    let mut w = World::new(e.world.clone(), e.setups.clone());
    // Mid-outage: the churners are down and the stable server knows.
    w.run_until(120.0);
    assert!(!w.node(3).online && !w.node(4).online);
    for churner in [3u32, 4] {
        assert!(
            !w.node(1).view.is_alive(NodeId(churner), w.now()),
            "node 1 still sees churned-out node {churner} at t=120"
        );
    }
    // After the rejoin + a few gossip rounds: back in the views.
    w.run_until(300.0);
    for churner in [3u32, 4] {
        assert!(
            w.node(1).view.is_alive(NodeId(churner), w.now()),
            "node 1 never saw node {churner} rejoin"
        );
    }
    assert!(w.recorder.len() > 10, "workload barely ran");
}

#[test]
fn group_start_offline_keeps_fleet_down_until_join() {
    let cfg = r#"{
        "seed": 6, "horizon": 100,
        "topology": {
            "regions": ["us"],
            "fleet": [
                { "region": "us", "count": 2,
                  "node": { "policy": { "stake": 20 } } },
                { "region": "us", "count": 2, "start_offline": true,
                  "churn": [ { "at": 50, "action": "join", "count": 2 } ] }
            ]
        }
    }"#;
    let e = parse_experiment(cfg).expect("config parses");
    assert!(e.setups[2].start_offline && e.setups[3].start_offline);
    let mut w = World::new(e.world.clone(), e.setups.clone());
    w.run_until(40.0);
    assert!(!w.node(2).online && !w.node(3).online);
    w.run_until(100.0);
    assert!(w.node(2).online && w.node(3).online);
    assert!(
        w.node(0).view.is_alive(NodeId(2), w.now()),
        "joined node never gossiped alive"
    );
}
