//! Property tests for the live latency estimator (`rust/src/latency/`),
//! in the repo's seeded-generator mini-framework style (`prop_ledger.rs`,
//! `prop_protocol.rs`).
//!
//! Invariants, under arbitrary observe / timeout / merge / touch / decay
//! interleavings:
//!
//! * **boundedness** — a cell's blended estimate never escapes the hull
//!   of its prior and every sample ever aimed at it (the EWMA is a convex
//!   combination; the prior blend and the staleness decay only pull it
//!   *toward* the prior);
//! * **decay monotonicity** — once evidence stops, the estimate moves
//!   monotonically toward the prior and reaches it after `decay_after`
//!   seconds of silence;
//! * **version discipline** — `version()` is monotone non-decreasing,
//!   pure reads and freshness-only touches never bump it, and the
//!   drift-quantized bump fires on every first observation of a cell;
//! * **disabled = frozen** — with `enabled: false` every estimate stays
//!   pinned at the prior and the version at 0, whatever is fed in.

use wwwserve::latency::{LatencyConfig, LatencyEstimator};
use wwwserve::util::rng::Rng;

const CASES: u64 = 60;
const OPS: usize = 80;

fn random_prior(rng: &mut Rng, n: usize) -> Vec<Vec<f64>> {
    (0..n)
        .map(|_| (0..n).map(|_| rng.range_f64(0.001, 0.3)).collect())
        .collect()
}

fn random_config(rng: &mut Rng) -> LatencyConfig {
    LatencyConfig {
        enabled: true,
        alpha: rng.range_f64(0.05, 1.0),
        decay_after: rng.range_f64(5.0, 120.0),
        prior_weight: rng.range_f64(0.0, 3.0),
        share_every: rng.range_f64(0.0, 10.0),
    }
}

/// Per-cell hull of everything that could have moved the estimate: the
/// prior plus every sample aimed at the cell (samples skipped by the
/// direct-trust holdoff only widen the hull, which stays sound).
struct Hull {
    lo: Vec<f64>,
    hi: Vec<f64>,
}

impl Hull {
    fn new(prior: &[Vec<f64>]) -> Hull {
        let flat: Vec<f64> = prior.iter().flatten().copied().collect();
        Hull { lo: flat.clone(), hi: flat }
    }

    fn feed(&mut self, n: usize, a: usize, b: usize, sample: f64) {
        let i = a * n + b;
        self.lo[i] = self.lo[i].min(sample);
        self.hi[i] = self.hi[i].max(sample);
    }
}

/// Drive one estimator through a random op tape; returns the hull, the
/// end time, and the estimator itself for post-tape checks.
fn drive(case: u64) -> (LatencyEstimator, Hull, f64) {
    let mut rng = Rng::new(0xC0FFEE ^ case);
    let n = 2 + rng.below(4);
    let my = rng.below(n) as u32;
    let prior = random_prior(&mut rng, n);
    let cfg = random_config(&mut rng);
    let mut est = LatencyEstimator::new(my, prior.clone(), cfg);
    let mut hull = Hull::new(&prior);
    let mut now = 0.0;
    let mut last_version = est.version();
    for _ in 0..OPS {
        now += rng.range_f64(0.0, cfg.decay_after * 0.6);
        let r = rng.below(n) as u32;
        match rng.below(4) {
            0 => {
                let rtt = rng.range_f64(0.0, 6.0);
                est.observe_rtt(r, rtt, now);
                hull.feed(n, my as usize, r as usize, rtt / 2.0);
                hull.feed(n, r as usize, my as usize, rtt / 2.0);
            }
            1 => {
                let timeout = rng.range_f64(0.5, 5.0);
                est.observe_timeout(r, timeout, now);
                hull.feed(n, my as usize, r as usize, timeout / 2.0);
                hull.feed(n, r as usize, my as usize, timeout / 2.0);
            }
            2 => {
                let a = rng.below(n) as u32;
                let b = rng.below(n) as u32;
                let v = rng.range_f64(0.0, 3.0);
                est.merge(&[(a, b, v)], now);
                hull.feed(n, a as usize, b as usize, v);
            }
            _ => {
                let before = est.version();
                est.touch(r, now);
                assert_eq!(
                    est.version(),
                    before,
                    "case {case}: freshness touch bumped the version"
                );
            }
        }
        let v = est.version();
        assert!(
            v >= last_version,
            "case {case}: version went backwards ({last_version} -> {v})"
        );
        last_version = v;
        // Bounded at every intermediate point, at the op time and later.
        check_bounds(&est, &hull, n, now, case);
        check_bounds(&est, &hull, n, now + rng.range_f64(0.0, 50.0), case);
    }
    (est, hull, now)
}

fn check_bounds(
    est: &LatencyEstimator,
    hull: &Hull,
    n: usize,
    at: f64,
    case: u64,
) {
    for a in 0..n {
        for b in 0..n {
            let got = est.expected(a as u32, b as u32, at);
            let (lo, hi) = (hull.lo[a * n + b], hull.hi[a * n + b]);
            assert!(
                got >= lo - 1e-9 && got <= hi + 1e-9,
                "case {case}: cell ({a},{b}) escaped its hull at t={at}: \
                 {got} not in [{lo}, {hi}]"
            );
        }
    }
}

#[test]
fn prop_estimates_stay_inside_the_prior_sample_hull() {
    for case in 0..CASES {
        drive(case);
    }
}

#[test]
fn prop_silence_decays_monotonically_to_the_prior() {
    for case in 0..CASES {
        let (est, _hull, end) = drive(case);
        let n = est.num_regions();
        let cfg = est.config();
        for a in 0..n as u32 {
            for b in 0..n as u32 {
                let prior = {
                    // The prior is what a fully decayed cell returns.
                    est.expected(a, b, end + cfg.decay_after + 1.0)
                };
                let mut dist = f64::INFINITY;
                let steps = 12;
                for k in 0..=steps {
                    let t = end + cfg.decay_after * k as f64 / steps as f64;
                    let d = (est.expected(a, b, t) - prior).abs();
                    assert!(
                        d <= dist + 1e-9,
                        "case {case}: cell ({a},{b}) decay not monotone \
                         at step {k}: {d} > {dist}"
                    );
                    dist = d;
                }
                // Fully decayed: exactly the prior, and it stays there.
                let settled = est.expected(a, b, end + cfg.decay_after);
                assert!(
                    (settled - prior).abs() < 1e-9,
                    "case {case}: cell ({a},{b}) not settled after \
                     decay_after: {settled} vs {prior}"
                );
            }
        }
    }
}

#[test]
fn prop_version_bumps_on_first_observation_of_a_cell() {
    for case in 0..CASES {
        let mut rng = Rng::new(0xBEEF ^ case);
        let n = 2 + rng.below(4);
        let prior = random_prior(&mut rng, n);
        let cfg = random_config(&mut rng);
        let mut est = LatencyEstimator::new(0, prior, cfg);
        let mut seen = vec![false; n];
        let mut now = 0.0;
        for _ in 0..30 {
            now += rng.range_f64(0.1, 5.0);
            let r = 1 + rng.below(n - 1);
            let before = est.version();
            est.observe_rtt(r as u32, rng.range_f64(0.1, 4.0), now);
            if !seen[r] {
                assert!(
                    est.version() > before,
                    "case {case}: first observation of region {r} did \
                     not bump the version"
                );
                seen[r] = true;
            }
        }
    }
}

#[test]
fn prop_disabled_estimator_is_frozen_under_any_tape() {
    for case in 0..CASES {
        let mut rng = Rng::new(0xD15AB1ED ^ case);
        let n = 2 + rng.below(3);
        let my = rng.below(n) as u32;
        let prior = random_prior(&mut rng, n);
        let cfg = LatencyConfig { enabled: false, ..random_config(&mut rng) };
        let mut est = LatencyEstimator::new(my, prior.clone(), cfg);
        let mut now = 0.0;
        for _ in 0..40 {
            now += rng.range_f64(0.0, 20.0);
            let r = rng.below(n) as u32;
            match rng.below(4) {
                0 => est.observe_rtt(r, rng.range_f64(0.0, 6.0), now),
                1 => est.observe_timeout(r, rng.range_f64(0.5, 5.0), now),
                2 => est.merge(&[(r, my, rng.range_f64(0.0, 3.0))], now),
                _ => est.touch(r, now),
            }
            assert_eq!(est.version(), 0, "case {case}: frozen version moved");
            assert!(
                est.share(now).is_empty(),
                "case {case}: frozen estimator shared a summary"
            );
        }
        for a in 0..n {
            for b in 0..n {
                assert_eq!(
                    est.expected(a as u32, b as u32, now),
                    prior[a][b],
                    "case {case}: frozen cell ({a},{b}) moved off prior"
                );
            }
        }
    }
}
