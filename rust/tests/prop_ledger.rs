//! Property-based tests on ledger invariants (mini-framework: seeded
//! generators + many-case loops, standing in for proptest — DESIGN.md §8).
//!
//! Invariants:
//! * conservation: total credits + burned == minted, under ANY op sequence
//! * validation: no account ever goes negative, stakes never exceed holdings
//! * chain: an audited chain replays to exactly the same balances
//! * tamper-evidence: any byte of history changing breaks the audit

use wwwserve::crypto::{KeyStore, NodeKey};
use wwwserve::ledger::{
    BalanceTable, Block, Chain, CreditOp, Ledger, OpReason, SharedLedger,
};
use wwwserve::util::rng::Rng;
use wwwserve::NodeId;

const CASES: usize = 200;

fn random_op(rng: &mut Rng, n_nodes: u32) -> CreditOp {
    let node = || NodeId(0); // placeholder, replaced below
    let _ = node;
    let a = NodeId(rng.below(n_nodes as usize) as u32);
    let b = NodeId(rng.below(n_nodes as usize) as u32);
    let amount = 1 + rng.next_u64() % 500;
    match rng.below(5) {
        0 => CreditOp::Mint { to: a, amount, reason: OpReason::Genesis },
        1 => CreditOp::Slash { from: a, amount, reason: OpReason::PolicyAdjust },
        2 => CreditOp::Transfer {
            from: a,
            to: b,
            amount,
            reason: OpReason::PolicyAdjust,
        },
        3 => CreditOp::Stake { node: a, amount },
        _ => CreditOp::Unstake { node: a, amount },
    }
}

#[test]
fn prop_conservation_under_arbitrary_ops() {
    for case in 0..CASES {
        let mut rng = Rng::new(case as u64);
        let mut table = BalanceTable::new();
        let n_ops = 1 + rng.below(100);
        let mut applied = 0;
        for _ in 0..n_ops {
            let op = random_op(&mut rng, 5);
            if table.apply(&op).is_ok() {
                applied += 1;
            }
            assert!(
                table.conserved(),
                "case {case}: conservation broken after {applied} ops"
            );
        }
    }
}

#[test]
fn prop_no_negative_balances() {
    for case in 0..CASES {
        let mut rng = Rng::new(1000 + case as u64);
        let mut table = BalanceTable::new();
        for _ in 0..rng.below(120) + 1 {
            let op = random_op(&mut rng, 4);
            let _ = table.apply(&op);
            for i in 0..4u32 {
                // Credits are u64 so negativity shows up as huge values
                // after a hypothetical underflow.
                let acct = table.account(NodeId(i));
                assert!(acct.balance < u64::MAX / 2, "case {case}: underflow");
                assert!(acct.stake < u64::MAX / 2, "case {case}: underflow");
            }
        }
    }
}

#[test]
fn prop_shared_ledger_batches_are_atomic() {
    for case in 0..CASES {
        let mut rng = Rng::new(2000 + case as u64);
        let mut ledger = SharedLedger::new();
        ledger
            .submit(
                vec![CreditOp::Mint {
                    to: NodeId(0),
                    amount: 1000,
                    reason: OpReason::Genesis,
                }],
                NodeId(0),
                0.0,
            )
            .unwrap();
        let before_total = ledger.table().total_credits();
        let before_log = ledger.log().len();
        let batch: Vec<CreditOp> =
            (0..rng.below(6) + 1).map(|_| random_op(&mut rng, 3)).collect();
        let result = ledger.submit(batch.clone(), NodeId(0), 1.0);
        if result.is_err() {
            // Failed batches must leave no trace.
            assert_eq!(ledger.table().total_credits(), before_total);
            assert_eq!(ledger.log().len(), before_log);
        } else {
            assert_eq!(ledger.log().len(), before_log + batch.len());
        }
        assert!(ledger.table().conserved());
    }
}

#[test]
fn prop_chain_replay_matches_balances() {
    let keys = KeyStore::for_network(9, 4);
    for case in 0..60 {
        let mut rng = Rng::new(3000 + case as u64);
        let mut chain = Chain::new();
        // Build a random valid chain.
        for b in 0..rng.below(10) + 1 {
            let proposer = NodeKey::derive(9, NodeId(rng.below(4) as u32));
            let mut ops = Vec::new();
            for _ in 0..rng.below(5) + 1 {
                ops.push(random_op(&mut rng, 4));
            }
            let block =
                Block::create(chain.head(), b as f64, ops, &proposer);
            // Only commit blocks whose ops validate.
            let _ = chain.commit_block(block, &keys);
        }
        assert!(chain.audit(&keys), "case {case}: audit failed");
        // Replay from scratch must give identical balances.
        let mut replay = BalanceTable::new();
        for block in chain.blocks() {
            for op in &block.ops {
                replay.apply(op).expect("committed ops must be valid");
            }
        }
        for i in 0..4u32 {
            assert_eq!(replay.account(NodeId(i)), {
                chain.balances().account(NodeId(i))
            });
        }
    }
}

#[test]
fn prop_any_tamper_breaks_audit() {
    let keys = KeyStore::for_network(5, 3);
    for case in 0..60 {
        let mut rng = Rng::new(4000 + case as u64);
        let mut chain = Chain::new();
        for b in 0..3 {
            let proposer = NodeKey::derive(5, NodeId(rng.below(3) as u32));
            let ops = vec![CreditOp::Mint {
                to: NodeId(rng.below(3) as u32),
                amount: 1 + rng.next_u64() % 100,
                reason: OpReason::Genesis,
            }];
            let block = Block::create(chain.head(), b as f64, ops, &proposer);
            chain.commit_block(block, &keys).unwrap();
        }
        assert!(chain.audit(&keys));
        // Tamper with a random committed op.
        let mut blocks = chain.blocks().to_vec();
        let bi = rng.below(blocks.len());
        blocks[bi].ops[0] = CreditOp::Mint {
            to: NodeId(0),
            amount: 999_999,
            reason: OpReason::Genesis,
        };
        let mut forged = Chain::new();
        let mut all_ok = true;
        for b in blocks {
            if forged.commit_block(b, &keys).is_err() {
                all_ok = false;
                break;
            }
        }
        assert!(
            !all_ok || !forged.audit(&keys),
            "case {case}: tampering went undetected"
        );
    }
}
