//! Property tests on the protocol substrates: gossip convergence under
//! arbitrary topologies/churn, PoS sampling proportionality, batcher
//! invariants, replicator simplex preservation.

use wwwserve::gossip::{GossipConfig, PeerView};
use wwwserve::gametheory::{NodeParams, Replicator, SystemParams};
use wwwserve::pos::StakeSnapshot;
use wwwserve::runtime::Batcher;
use wwwserve::util::rng::Rng;
use wwwserve::NodeId;

#[test]
fn prop_gossip_converges_on_any_connected_bootstrap() {
    // Random connected bootstrap graphs (spanning tree + extra edges):
    // every node must learn full membership within a bounded number of
    // push-pull rounds.
    for case in 0..40 {
        let mut rng = Rng::new(case);
        let n = 4 + rng.below(24);
        let cfg = GossipConfig {
            interval: 1.0,
            fanout: 2,
            suspect_after: 1e9,
            ..Default::default()
        };
        let mut views: Vec<PeerView> = (0..n)
            .map(|i| PeerView::new(NodeId(i as u32), cfg, 0.0))
            .collect();
        // Spanning tree: node i knows a random earlier node.
        for i in 1..n {
            let j = rng.below(i);
            views[i].add_seed(NodeId(j as u32), 0, 0, 0.0);
            views[j].add_seed(NodeId(i as u32), 0, 0, 0.0);
        }
        let mut converged_at = None;
        for round in 1..=80 {
            let now = round as f64;
            for v in views.iter_mut() {
                v.heartbeat(now);
            }
            for i in 0..n {
                for t in views[i].pick_targets(&mut rng, now) {
                    let d = views[i].digest();
                    views[t.0 as usize].merge(&d, now);
                    let back = views[t.0 as usize].digest();
                    views[i].merge(&back, now);
                }
            }
            if views.iter().all(|v| v.known() == n) {
                converged_at = Some(round);
                break;
            }
        }
        let r = converged_at
            .unwrap_or_else(|| panic!("case {case}: n={n} never converged"));
        assert!(r <= 60, "case {case}: n={n} took {r} rounds");
    }
}

#[test]
fn prop_gossip_leave_detected_everywhere() {
    for case in 0..30 {
        let mut rng = Rng::new(100 + case);
        let n = 4 + rng.below(12);
        let cfg = GossipConfig {
            interval: 1.0,
            fanout: 2,
            suspect_after: 1e9,
            ..Default::default()
        };
        let mut views: Vec<PeerView> = (0..n)
            .map(|i| PeerView::new(NodeId(i as u32), cfg, 0.0))
            .collect();
        for i in 0..n {
            views[i].add_seed(NodeId(((i + 1) % n) as u32), 0, 0, 0.0);
        }
        // Converge membership first.
        for round in 1..=40 {
            let now = round as f64;
            for v in views.iter_mut() {
                v.heartbeat(now);
            }
            for i in 0..n {
                for t in views[i].pick_targets(&mut rng, now) {
                    let d = views[i].digest();
                    views[t.0 as usize].merge(&d, now);
                    let back = views[t.0 as usize].digest();
                    views[i].merge(&back, now);
                }
            }
        }
        // Node 0 gracefully leaves; keep gossiping without it.
        let leaver = rng.below(n);
        views[leaver].announce_leave(41.0);
        let goodbye = views[leaver].digest();
        let first = (leaver + 1) % n;
        views[first].merge(&goodbye, 41.0);
        for round in 42..=90 {
            let now = round as f64;
            for i in 0..n {
                if i == leaver {
                    continue;
                }
                views[i].heartbeat(now);
                for t in views[i].pick_targets(&mut rng, now) {
                    if t.0 as usize == leaver {
                        continue; // it's gone
                    }
                    let d = views[i].digest();
                    views[t.0 as usize].merge(&d, now);
                    let back = views[t.0 as usize].digest();
                    views[i].merge(&back, now);
                }
            }
        }
        for (i, v) in views.iter().enumerate() {
            if i == leaver {
                continue;
            }
            assert!(
                !v.is_alive(NodeId(leaver as u32), 91.0),
                "case {case}: node {i} still believes {leaver} alive"
            );
        }
    }
}

#[test]
fn prop_pos_sampling_tracks_stakes() {
    for case in 0..20 {
        let mut rng = Rng::new(200 + case);
        let n = 2 + rng.below(12);
        let stakes: Vec<(NodeId, u64)> = (0..n)
            .map(|i| (NodeId(i as u32), rng.next_u64() % 1000))
            .collect();
        let total: u64 = stakes.iter().map(|(_, s)| *s).sum();
        if total == 0 {
            continue;
        }
        let mut snap = StakeSnapshot::new(&stakes, None);
        snap.prepare();
        let draws = 60_000;
        let mut counts = vec![0usize; n];
        for _ in 0..draws {
            if let Some(pick) = snap.sample(&mut rng) {
                counts[pick.0 as usize] += 1;
            }
        }
        for (i, (_, s)) in stakes.iter().enumerate() {
            let expected = *s as f64 / total as f64;
            let got = counts[i] as f64 / draws as f64;
            assert!(
                (got - expected).abs() < 0.02,
                "case {case}: node {i} share {got:.3} vs stake share {expected:.3}"
            );
        }
    }
}

#[test]
fn prop_batcher_waves_cover_exactly() {
    for case in 0..200 {
        let mut rng = Rng::new(300 + case);
        let mut sizes: Vec<usize> =
            (0..rng.below(4) + 1).map(|_| 1 << rng.below(5)).collect();
        sizes.push(1); // ensure coverage of any n
        let batcher = Batcher::new(sizes.clone());
        let n = rng.below(100);
        let waves = batcher.waves(n);
        let covered: usize = waves.iter().sum();
        assert!(covered >= n, "case {case}: waves under-cover {covered}<{n}");
        // No wave exceeds the largest compiled size; waste is < one wave.
        for w in &waves {
            assert!(batcher.pick(*w) == *w, "case {case}: non-compiled wave");
        }
        assert!(
            covered - n < batcher.max_batch(),
            "case {case}: waste {covered}-{n} too large"
        );
    }
}

#[test]
fn prop_replicator_stays_on_simplex() {
    for case in 0..50 {
        let mut rng = Rng::new(400 + case);
        let n = 2 + rng.below(8);
        let nodes: Vec<NodeParams> = (0..n)
            .map(|_| NodeParams {
                quality: rng.f64(),
                cost: 0.1 + rng.f64(),
                stake0: 0.1 + rng.f64() * 5.0,
            })
            .collect();
        let sys = SystemParams {
            lambda: 1.0 + rng.f64() * 20.0,
            base_reward: rng.f64() * 2.0,
            duel_rate: rng.f64(),
            duel_reward: rng.f64() * 3.0,
            duel_penalty: rng.f64() * 3.0,
            eta: 0.1 + rng.f64(),
        };
        let mut r = Replicator::new(nodes, sys);
        for step in 0..2000 {
            r.step(0.01);
            let shares = r.shares();
            let sum: f64 = shares.iter().sum();
            assert!(
                sum == 0.0 || (sum - 1.0).abs() < 1e-9,
                "case {case} step {step}: simplex violated (sum {sum})"
            );
            for s in &shares {
                assert!(
                    (0.0..=1.0 + 1e-12).contains(s),
                    "case {case}: share out of range {s}"
                );
            }
            for q in 0..r.nodes.len() {
                let w = r.win_prob(q);
                assert!((0.0..=1.0).contains(&w));
            }
        }
    }
}
