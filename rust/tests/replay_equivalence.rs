//! Replay equivalence across the coordinator decomposition seam.
//!
//! The Node god-object was split into a layered pipeline (dispatch / duel /
//! gossip_driver / latency_feed / snapshot) with a pluggable
//! `ParticipationPolicy` at the dispatch boundary. The contract: with
//! `DefaultPolicy` the decomposed node makes exactly the same decisions —
//! draw for draw on the same RNG stream — as the pre-refactor scalar-knob
//! code, whose behaviour survives verbatim in `NodePolicy::should_offload`
//! / `should_accept` (every pre-refactor unit test still runs against the
//! decomposed node, unchanged).
//!
//! These tests pin the seam on the geo_scale smoke scenario (3-region WAN,
//! follow-the-sun diurnal load, mid-run partition + heal) by comparing
//! full `World` trace fingerprints: record counts and latency sums,
//! per-region SLO attainment, message/byte/drop counters, duel
//! settlements, and end-state credit totals — if any event ordering, RNG
//! draw, payment or settlement diverges, these collapse.

use wwwserve::config::parse_experiment;
use wwwserve::policy::{DefaultPolicy, RequesterOnly};
use wwwserve::sim::World;

const HORIZON: f64 = 400.0;

/// The geo_scale smoke scenario, declaratively: one requester + two
/// servers per region, offset diurnal peaks, us<->asia partition at 150 s
/// healed at 250 s. `policy_keys` toggles the declarative participation
/// selection so the legacy (no keys) and explicit (`"policy": "default"`)
/// forms can be compared.
fn geo_smoke_config(policy_keys: bool, requester_policy: &str) -> String {
    geo_smoke_config_capacity(policy_keys, requester_policy, "")
}

/// Same scenario with an optional `capacity` block (e.g.
/// `r#", "capacity": { "policy": "static" }"#`) appended to every server
/// group — the replay seam for the elastic-capacity subsystem.
fn geo_smoke_config_capacity(
    policy_keys: bool,
    requester_policy: &str,
    capacity: &str,
) -> String {
    let req_policy = if policy_keys {
        format!(r#""policy": "{requester_policy}","#)
    } else {
        String::new()
    };
    let srv_policy = if policy_keys {
        r#""policy": "default","#.to_string()
    } else {
        String::new()
    };
    let mut groups = Vec::new();
    for (region, offset) in [("us", 0.0), ("eu", 100.0), ("asia", 200.0)] {
        groups.push(format!(
            r#"{{ "region": "{region}", "count": 1, {req_policy}
                 "node": {{
                   "profile": {{ "prefill_tok_s": 2000, "decode_tok_s": 40,
                                 "max_agg_decode_tok_s": 160,
                                 "max_batch": 4 }},
                   "policy": {{ "stake": 0, "offload_freq": 1.0,
                                "accept_freq": 0.0, "requester_only": true,
                                "latency_penalty": 50.0 }} }},
                 "diurnal": {{ "period": 300, "peak_inter_arrival": 2.5,
                               "off_inter_arrival": 25,
                               "offset": {offset} }},
                 "lengths": {{ "output_mean": 900,
                               "output_sigma": 0.5 }} }}"#
        ));
        groups.push(format!(
            r#"{{ "region": "{region}", "count": 2, {srv_policy}
                 "node": {{
                   "profile": {{ "prefill_tok_s": 4000, "decode_tok_s": 45,
                                 "max_agg_decode_tok_s": 1080,
                                 "max_batch": 24 }},
                   "policy": {{ "stake": 20, "accept_freq": 1.0,
                                "latency_penalty": 50.0 }} }}{capacity} }}"#
        ));
    }
    format!(
        r#"{{
            "seed": 2026,
            "horizon": {HORIZON},
            "system": {{ "duel_rate": 0.1 }},
            "topology": {{
                "regions": ["us", "eu", "asia"],
                "intra": {{ "latency": [0.002, 0.010] }},
                "inter": {{ "latency": [0.040, 0.080], "jitter": 0.005 }},
                "events": [
                    {{ "at": 150, "a": "us", "b": "asia",
                       "change": "partition" }},
                    {{ "at": 250, "a": "us", "b": "asia", "change": "heal" }}
                ],
                "fleet": [ {} ]
            }}
        }}"#,
        groups.join(", ")
    )
}

/// Everything observable about a finished world, quantized for exact
/// comparison: messages, settlements, SLO attainment, credits.
type Fingerprint = (
    usize,
    u64,
    u64,
    u64,
    u64,
    u64,
    usize,
    Vec<(String, u64, u64, usize)>,
    Vec<u64>,
    (u64, u64),
);

fn fingerprint(w: &World) -> Fingerprint {
    (
        w.recorder.len(),
        (w.recorder.mean_latency() * 1e9) as u64,
        w.messages_sent,
        w.bytes_sent,
        w.messages_dropped,
        w.gossip_bytes_sent,
        w.duel_stats.total_duels(),
        w.region_summary()
            .into_iter()
            .map(|(name, slo, p99, n)| {
                (name, (slo * 1e9) as u64, (p99 * 1e9) as u64, n)
            })
            .collect(),
        w.credit_totals().iter().map(|c| (c * 1e6) as u64).collect(),
        (w.kv_transfer_count, w.kv_transfer_bytes),
    )
}

fn run(config: &str) -> Fingerprint {
    let e = parse_experiment(config).expect("config parses");
    let mut w = World::new(e.world.clone(), e.setups.clone());
    w.run_until(HORIZON + 600.0);
    assert!(
        w.recorder.len() > 50,
        "scenario barely ran: {} records",
        w.recorder.len()
    );
    fingerprint(&w)
}

#[test]
fn decomposed_node_replays_bit_identically() {
    let cfg = geo_smoke_config(false, "default");
    assert_eq!(run(&cfg), run(&cfg), "same seed, same trace");
}

#[test]
fn explicit_default_policy_matches_legacy_path() {
    // Selecting `policy: "default"` declaratively must be a no-op against
    // the key-less legacy form — the trait seam adds nothing to the trace.
    let legacy = run(&geo_smoke_config(false, "default"));
    let explicit = run(&geo_smoke_config(true, "default"));
    assert_eq!(
        legacy, explicit,
        "declarative default participation diverged from the legacy path"
    );
}

#[test]
fn requester_only_trait_matches_scalar_knob() {
    // The requester groups carry the scalar `requester_only: true` knob in
    // both runs; the second additionally routes them through the
    // `RequesterOnly` participation object. Bit-identical traces prove the
    // policy object replaces the special-cased knob exactly.
    let knob = run(&geo_smoke_config(false, "default"));
    let trait_based = run(&geo_smoke_config(true, "requester_only"));
    assert_eq!(
        knob, trait_based,
        "RequesterOnly policy diverged from the requester_only knob"
    );
}

#[test]
fn static_capacity_block_replays_the_capacity_free_trace() {
    // The elastic-capacity seam's replay contract: declaring
    // `capacity: {policy: "static"}` on every server group — commitment
    // declared, no controller installed — must leave the full World trace
    // identical to a config with no capacity subsystem at all. An absent
    // block is the same parse path as the baseline, pinned for symmetry.
    let absent = run(&geo_smoke_config(false, "default"));
    let static_block = run(&geo_smoke_config_capacity(
        false,
        "default",
        r#", "capacity": { "policy": "static" }"#,
    ));
    assert_eq!(
        absent, static_block,
        "static capacity declaration perturbed the trace"
    );
    // Sanity: the static config really does carry parsed capacity specs —
    // the equivalence above is the controller-gating seam at work, not a
    // silently dropped block.
    let e = parse_experiment(&geo_smoke_config_capacity(
        false,
        "default",
        r#", "capacity": { "policy": "static" }"#,
    ))
    .expect("config parses");
    assert_eq!(e.world.capacity.len(), 3, "one spec per server group");
    assert!(e
        .world
        .capacity
        .iter()
        .all(|s| s.cfg.policy == wwwserve::capacity::CapacityPolicyKind::Static));
}

#[test]
fn reactive_capacity_changes_the_trace_but_replays_deterministically() {
    // The controller is live machinery: a reactive block must be
    // bit-reproducible from the seed (no hidden RNG in the control loop),
    // while genuinely diverging from the capacity-free trace.
    let cap = r#", "capacity": { "policy": "reactive", "standby": 1,
                   "scale_up_util": 0.7, "scale_down_util": 0.2,
                   "cooldown": 6, "eval_every": 2,
                   "online_cost_per_hour": 1.0,
                   "standby_cost_per_hour": 0.1 }"#;
    let cfg = geo_smoke_config_capacity(false, "default", cap);
    let a = run(&cfg);
    let b = run(&cfg);
    assert_eq!(a, b, "reactive capacity world is not deterministic");
    let baseline = run(&geo_smoke_config(false, "default"));
    assert_ne!(
        a, baseline,
        "reactive capacity had no observable effect at all"
    );
}

#[test]
fn observability_disabled_block_replays_the_baseline_trace() {
    // An explicit `observability: {enabled: false}` block must be the same
    // parse-and-run path as no block at all — the flight recorder stays a
    // zero-capacity stub and nothing about the trace moves.
    let baseline = run(&geo_smoke_config(false, "default"));
    let cfg = geo_smoke_config(false, "default").replace(
        "\"seed\": 2026,",
        "\"seed\": 2026, \"observability\": { \"enabled\": false },",
    );
    assert!(cfg.contains("observability"), "splice failed");
    assert_eq!(
        baseline,
        run(&cfg),
        "disabled observability block perturbed the trace"
    );
}

#[test]
fn observability_enabled_is_purely_observational() {
    // Tracing ON must still replay the baseline fingerprint bit for bit:
    // spans and registry samples ride along with zero queue events, zero
    // RNG draws, zero counter changes. That's the whole contract that
    // makes the flight recorder safe to leave on in production runs.
    let baseline = run(&geo_smoke_config(false, "default"));
    let cfg = geo_smoke_config(false, "default").replace(
        "\"seed\": 2026,",
        "\"seed\": 2026, \"observability\": { \"enabled\": true },",
    );
    assert!(cfg.contains("observability"), "splice failed");
    let e = parse_experiment(&cfg).expect("config parses");
    assert!(e.world.observability.enabled);
    let mut w = World::new(e.world.clone(), e.setups.clone());
    w.run_until(HORIZON + 600.0);
    assert_eq!(
        baseline,
        fingerprint(&w),
        "enabled observability perturbed the trace"
    );
    // And it actually observed the run: span trees were recorded and the
    // registry mirrors the world counters.
    assert!(!w.span_trees().is_empty(), "no traces recorded");
    let events = w
        .registry()
        .get("events_processed", &[])
        .expect("events_processed metric");
    assert_eq!(events.value, w.events_processed as f64);
}

#[test]
fn streaming_disabled_block_replays_the_baseline_trace() {
    // The streaming seam's replay contract: an explicit
    // `streaming: {enabled: false}` block must be the same parse-and-run
    // path as no block at all — dispatch stays session-blind, admission
    // unified, no KvTransfer ever hits the wire, and the RNG draw
    // sequence is untouched bit for bit.
    let baseline = run(&geo_smoke_config(false, "default"));
    let cfg = geo_smoke_config(false, "default").replace(
        "\"seed\": 2026,",
        "\"seed\": 2026, \"streaming\": { \"enabled\": false },",
    );
    assert!(cfg.contains("streaming"), "splice failed");
    let e = parse_experiment(&cfg).expect("config parses");
    assert!(!e.world.streaming.enabled);
    assert_eq!(
        baseline,
        run(&cfg),
        "disabled streaming block perturbed the trace"
    );
    // The baseline world ships zero session KV, by construction.
    assert_eq!(baseline.9, (0, 0));
}

#[test]
fn streaming_enabled_changes_trace_but_replays_deterministically() {
    // Armed streaming is live machinery: split-pool admission reshapes
    // completion times, session turns carry TTFT budgets, and KV-affine
    // dispatch changes who executes what. The trace must genuinely
    // diverge from the baseline while staying bit-reproducible.
    let cfg = geo_smoke_config(false, "default")
        .replace(
            "\"seed\": 2026,",
            "\"seed\": 2026, \"streaming\": { \"enabled\": true },",
        )
        .replace(
            "\"lengths\":",
            "\"sessions\": { \"turns_mean\": 3 }, \"lengths\":",
        );
    assert!(cfg.contains("streaming"), "splice failed");
    assert!(cfg.contains("sessions"), "sessions splice failed");
    let e = parse_experiment(&cfg).expect("config parses");
    assert!(e.world.streaming.enabled);
    let a = run(&cfg);
    let b = run(&cfg);
    assert_eq!(a, b, "streaming world is not deterministic");
    let baseline = run(&geo_smoke_config(false, "default"));
    assert_ne!(a, baseline, "streaming had no observable effect at all");
}

#[test]
fn defenses_disabled_block_replays_the_baseline_trace() {
    // The Byzantine-defense seam's replay contract: an explicit
    // `defenses: {enabled: false}` block must be the same parse-and-run
    // path as no block at all — no receipts on the wire, no reputation
    // rows in gossip, no hearsay capping, not one byte of the trace moved.
    let baseline = run(&geo_smoke_config(false, "default"));
    let cfg = geo_smoke_config(false, "default").replace(
        "\"seed\": 2026,",
        "\"seed\": 2026, \"defenses\": { \"enabled\": false },",
    );
    assert!(cfg.contains("defenses"), "splice failed");
    let e = parse_experiment(&cfg).expect("config parses");
    assert!(!e.world.defenses.enabled);
    assert_eq!(
        baseline,
        run(&cfg),
        "disabled defenses block perturbed the trace"
    );
}

#[test]
fn defenses_enabled_changes_the_trace_but_replays_deterministically() {
    // Armed defenses are live machinery (receipts cost wire bytes,
    // reputation reshapes snapshots): the trace must genuinely diverge
    // from the defenseless baseline while staying bit-reproducible.
    let cfg = geo_smoke_config(false, "default").replace(
        "\"seed\": 2026,",
        "\"seed\": 2026, \"defenses\": { \"enabled\": true },",
    );
    assert!(cfg.contains("defenses"), "splice failed");
    let a = run(&cfg);
    let b = run(&cfg);
    assert_eq!(a, b, "defended world is not deterministic");
    let baseline = run(&geo_smoke_config(false, "default"));
    assert_ne!(
        a.3, baseline.3,
        "armed defenses cost no wire bytes — receipts never attached?"
    );
}

#[test]
fn installing_default_policy_post_construction_is_a_noop() {
    let cfg = geo_smoke_config(false, "default");
    let e = parse_experiment(&cfg).expect("config parses");
    let mut plain = World::new(e.world.clone(), e.setups.clone());
    let mut swapped = World::new(e.world.clone(), e.setups.clone());
    for i in 0..swapped.num_nodes() {
        swapped.node_mut(i).set_participation(Box::new(DefaultPolicy));
        assert_eq!(swapped.node(i).participation().name(), "default");
    }
    plain.run_until(HORIZON + 600.0);
    swapped.run_until(HORIZON + 600.0);
    assert_eq!(fingerprint(&plain), fingerprint(&swapped));
}

#[test]
fn mixed_policy_world_replays_deterministically() {
    // Heterogeneous populations (default servers + requester_only +
    // greedy_local + selective) under partition/heal + churn must stay
    // bit-reproducible from the seed.
    let cfg = r#"{
        "seed": 9, "horizon": 300,
        "system": { "duel_rate": 0.0 },
        "topology": {
            "regions": ["us", "eu"],
            "intra": { "latency": [0.002, 0.010] },
            "inter": { "latency": [0.040, 0.080] },
            "fleet": [
                { "region": "us", "count": 1, "policy": "requester_only",
                  "node": { "policy": { "latency_penalty": 20.0 } },
                  "schedule": [ {"from": 0, "to": 300,
                                 "inter_arrival": 2} ],
                  "lengths": { "output_mean": 600, "output_sigma": 0.5 } },
                { "region": "us", "count": 2, "policy": "greedy_local",
                  "node": { "policy": { "stake": 20 } } },
                { "region": "eu", "count": 2, "policy": "selective",
                  "node": { "policy": { "stake": 20 } },
                  "churn": [ { "at": 100, "action": "leave" },
                             { "at": 200, "action": "join" } ] },
                { "region": "eu", "count": 2,
                  "node": { "policy": { "stake": 20,
                                        "accept_freq": 1.0 } } }
            ]
        }
    }"#;
    let go = || {
        let e = parse_experiment(cfg).expect("config parses");
        assert_eq!(e.churn.len(), 2, "churn parsed");
        assert_eq!(e.world.churn.len(), 2, "churn carried to the world");
        let mut w = World::new(e.world.clone(), e.setups.clone());
        w.run_until(900.0);
        fingerprint(&w)
    };
    let a = go();
    assert!(a.0 > 20, "mixed-policy world barely ran: {} records", a.0);
    assert_eq!(a, go(), "mixed-policy world is not deterministic");
}

#[test]
fn requester_only_policy_nodes_never_serve() {
    let cfg = geo_smoke_config(true, "requester_only");
    let e = parse_experiment(&cfg).expect("config parses");
    let mut w = World::new(e.world.clone(), e.setups.clone());
    w.run_until(HORIZON);
    // Requesters are nodes 0, 3, 6 (one per region, ahead of 2 servers).
    for i in [0usize, 3, 6] {
        assert_eq!(
            w.node(i).participation().name(),
            "requester_only",
            "node {i} runs the wrong policy"
        );
        assert_eq!(
            w.node(i).stats.delegated_in,
            0,
            "requester-only node {i} accepted delegated work"
        );
    }
    // Servers actually served delegated work.
    let served: u64 =
        (0..9).map(|i| w.node(i).stats.delegated_in).sum();
    assert!(served > 0, "nobody served anything");
}

#[test]
fn requester_only_trait_works_without_the_scalar_knob() {
    // RequesterOnly selected as an object on a default-knob node: always
    // offloads, never accepts — no `requester_only: true` knob in sight.
    use wwwserve::backend::{Profile, SimBackend};
    use wwwserve::coordinator::{Action, Event, LedgerManager, Message, Node};
    use wwwserve::gossip::GossipConfig;
    use wwwserve::ledger::SharedLedger;
    use wwwserve::policy::{NodePolicy, SystemPolicy};
    use wwwserve::types::{Request, RequestId};
    use wwwserve::NodeId;
    use std::sync::{Arc, Mutex};

    let shared = Arc::new(Mutex::new(SharedLedger::new()));
    let mk = |id: u32| {
        Node::new(
            NodeId(id),
            NodePolicy::default(),
            SystemPolicy::default(),
            Box::new(SimBackend::new(Profile::test(50.0, 4))),
            LedgerManager::shared(shared.clone()),
            GossipConfig::default(),
            42,
            0.0,
        )
    };
    let _server = mk(1);
    let mut n = mk(0);
    n.set_participation(Box::new(RequesterOnly));
    n.system.duel_rate = 0.0;
    n.view.merge(&[(NodeId(1), 1, true, 0, 0)], 0.0);
    let req = Request {
        id: RequestId { origin: NodeId(0), seq: 0 },
        prompt_tokens: 100,
        output_tokens: 100,
        submitted_at: 0.0,
        slo_deadline: 60.0,
        synthetic: false,
        payload: vec![],
        session: 0,
        ttft_deadline: f64::INFINITY,
    };
    // Idle backend, yet the request goes to the market.
    let a = n.handle(Event::UserRequest(req.clone()), 0.0);
    assert!(
        a.iter()
            .any(|x| matches!(x, Action::Send { msg: Message::Probe { .. }, .. })),
        "RequesterOnly must always offload: {a:?}"
    );
    // Incoming probes are refused outright.
    let a = n.handle(
        Event::Message {
            from: NodeId(1),
            msg: Message::Probe {
                req_id: RequestId { origin: NodeId(1), seq: 7 },
                prompt_tokens: 10,
                output_tokens: 10,
            },
        },
        0.1,
    );
    assert!(a.iter().any(|x| matches!(
        x,
        Action::Send { msg: Message::ProbeReject { .. }, .. }
    )));
}
